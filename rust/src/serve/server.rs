//! The `cast serve` HTTP server: a dependency-free `std::net` acceptor
//! + connection worker pool in front of the dynamic micro-batcher.
//!
//! Data path (DESIGN.md §Serving):
//!
//! ```text
//! accept loop ─→ conn queue ─→ conn workers ─→ job queue ─→ batch former
//!  (nonblock)    (bounded)     (HTTP parse,    (bounded,     (coalesce ≤ max_batch
//!                               route, wait     backpress)    rows, ≤ max_wait)
//!                               for reply)            │
//!                                                     ▼
//!                                    engine predict (per-worker Workspace)
//!                                                     │
//!                                    demux logits ─→ reply channels
//! ```
//!
//! Endpoints: `POST /predict` (JSON tokens → logits), `POST /generate`
//! (incremental decode streamed as close-delimited NDJSON — one line
//! per token, then a `{"done":…}` summary; see DESIGN.md §Generation),
//! `GET /models`, `POST /models/reload?model=`, `GET /healthz`
//! (liveness), `GET /readyz` (readiness: `ok`/`degraded`, 503 while
//! draining), `GET /metrics` (Prometheus text), `POST /admin/shutdown`.
//!
//! Resilience (DESIGN.md §Robustness): worker panics are caught and
//! contained (a panicking batch answers its jobs with 500 and the
//! worker restarts), per-request deadline budgets (`X-Deadline-Ms`
//! capped by `--deadline-ms`) shed queue-expired jobs with 503 +
//! `Retry-After`, and a per-model circuit breaker sheds fast while a
//! model's engine is failing consecutively.
//!
//! Graceful shutdown: SIGINT/SIGTERM (via [`install_signal_handlers`])
//! or `/admin/shutdown` flips a flag; the acceptor stops, connection
//! workers finish their current request with `Connection: close`, the
//! job queue closes once every connection worker has exited, and the
//! inference workers drain what remains — every request that was read
//! off a socket gets its response before `run` returns.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::data::batcher::pad_rows;
use crate::runtime::native::{cluster_stats, decode};
use crate::runtime::{DecodeSession, Executable, HostTensor, Scratch};
use crate::util::json::Json;
use crate::util::parallel::Queue;
use crate::util::rng::Rng;
use crate::util::trace;

use super::batcher::{run_batch, BatchFormer, PredictJob, ReplyErr};
use super::http::{HttpConn, Recv, Request};
use super::metrics::{Endpoint, Metrics};
use super::registry::{ModelEntry, Registry, BREAKER_OPEN};

/// How long a connection worker waits for its batch's reply before
/// answering 504 (covers a deep queue on a slow box, not a hang).
const PREDICT_TIMEOUT: Duration = Duration::from_secs(120);

/// Prompt tokens absorbed per `decode_prefill` call on `/generate`.
/// Chunking bounds per-call latency; the resulting cluster cache is
/// bit-identical to a monolithic prefill (see `integration_decode`).
const PREFILL_CHUNK: usize = 64;

/// Cap on one `/generate` request's `max_new_tokens`.
const MAX_NEW_TOKENS: usize = 4096;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Micro-batch row cap (1 = no batching, the baseline).
    pub max_batch: usize,
    /// How long the batch former waits for a batch to fill.
    pub max_wait: Duration,
    /// Bound on queued predict jobs (backpressure beyond it).
    pub queue_cap: usize,
    /// Connection workers = max concurrent in-flight requests.
    pub conn_workers: usize,
    /// Inference workers pulling batches (1 keeps arrival order).
    pub infer_workers: usize,
    /// Request body cap in bytes.
    pub max_body: usize,
    /// Cap in milliseconds on a client's `X-Deadline-Ms` budget; a job
    /// still queued past its budget is shed with 503 + `Retry-After`
    /// instead of computed.  0 disables client deadlines entirely.
    pub deadline_ms: u64,
    /// Consecutive engine failures that open a model's circuit breaker
    /// (`--breaker-failures`; applied when the registry is built).
    pub breaker_failures: u32,
    /// Open-state cooldown before the breaker admits a probe
    /// (`--breaker-cooldown-ms`).
    pub breaker_cooldown: Duration,
    /// How many completed /predict stage traces `/debug/trace` retains
    /// (`--trace-ring`; clamped to at least 1).
    pub trace_ring: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8477".to_string(),
            max_batch: 8,
            max_wait: Duration::from_micros(2000),
            queue_cap: 256,
            conn_workers: 32,
            infer_workers: 1,
            max_body: 8 << 20,
            deadline_ms: 60_000,
            breaker_failures: 5,
            breaker_cooldown: Duration::from_secs(5),
            trace_ring: 256,
        }
    }
}

/// Process-global flag flipped by SIGINT/SIGTERM.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

/// Install SIGINT/SIGTERM handlers that request a graceful drain.  The
/// handler only stores to an atomic (async-signal-safe); the accept
/// loop polls the flag.  Dependency-free: `signal(2)` is declared
/// directly against libc, which every Rust binary already links.
#[cfg(unix)]
pub fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        SIGNALLED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
pub fn install_signal_handlers() {}

/// One completed /predict request's stage split, kept for `/debug/trace`.
struct TraceRow {
    seq: u64,
    model: String,
    rows: usize,
    status: u16,
    /// [parse, queue, batch, compute, reply] in µs, see `metrics::STAGES`.
    stages_us: [u64; 5],
}

impl TraceRow {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seq", Json::num(self.seq as f64)),
            ("model", Json::str(&self.model)),
            ("rows", Json::num(self.rows as f64)),
            ("status", Json::num(self.status as f64)),
        ];
        let keys = ["parse_us", "queue_us", "batch_us", "compute_us", "reply_us"];
        for (key, us) in keys.iter().zip(self.stages_us) {
            fields.push((*key, Json::num(us as f64)));
        }
        fields.push(("total_us", Json::num(self.stages_us.iter().sum::<u64>() as f64)));
        Json::obj(fields)
    }
}

pub struct Server {
    listener: TcpListener,
    local_addr: SocketAddr,
    cfg: ServeConfig,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    jobs: Arc<Queue<PredictJob>>,
    /// Ring of the last `cfg.trace_ring` completed /predict stage splits.
    recent: Mutex<VecDeque<TraceRow>>,
    trace_seq: AtomicU64,
}

impl Server {
    /// Bind the listen socket (use port 0 for an ephemeral test port).
    pub fn bind(cfg: ServeConfig, registry: Arc<Registry>) -> Result<Server> {
        anyhow::ensure!(!registry.is_empty(), "no models loaded — nothing to serve");
        let listener = TcpListener::bind(cfg.addr.as_str())
            .with_context(|| format!("binding {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let trace_ring = cfg.trace_ring.max(1);
        Ok(Server {
            listener,
            local_addr,
            jobs: Arc::new(Queue::bounded(cfg.queue_cap)),
            cfg,
            registry,
            metrics: Arc::new(Metrics::new()),
            shutdown: Arc::new(AtomicBool::new(false)),
            recent: Mutex::new(VecDeque::with_capacity(trace_ring)),
            trace_seq: AtomicU64::new(0),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Flag that triggers a graceful drain when set (tests use this in
    /// place of a signal).
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || SIGNALLED.load(Ordering::SeqCst)
    }

    /// Serve until shutdown, then drain and return.
    pub fn run(&self) -> Result<()> {
        crate::info!(
            "serve: listening on {} — {} model(s), max_batch {}, max_wait {:?}, {} conn / {} infer workers",
            self.local_addr,
            self.registry.len(),
            self.cfg.max_batch,
            self.cfg.max_wait,
            self.cfg.conn_workers,
            self.cfg.infer_workers
        );
        let conns: Queue<TcpStream> = Queue::bounded(self.cfg.conn_workers.max(1) * 4);
        std::thread::scope(|s| {
            let (max_batch, max_wait) = (self.cfg.max_batch, self.cfg.max_wait);
            let infer_handles: Vec<_> = (0..self.cfg.infer_workers.max(1))
                .map(|_| {
                    let jobs = self.jobs.clone();
                    let metrics = self.metrics.clone();
                    s.spawn(move || {
                        // restart the loop on an escaped panic
                        // (run_batch already contains per-batch panics;
                        // this guards the former itself).  Jobs held by
                        // the dead former drop their reply channels, so
                        // their conn workers answer 500 — nothing hangs.
                        loop {
                            let (jobs, metrics) = (jobs.clone(), metrics.clone());
                            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || infer_loop(jobs, max_batch, max_wait, metrics),
                            ));
                            match r {
                                Ok(()) => break,
                                Err(_) => {
                                    self.metrics.inc_worker_panic();
                                    crate::info!("serve: inference worker panicked; restarting");
                                }
                            }
                        }
                    })
                })
                .collect();
            let conn_handles: Vec<_> = (0..self.cfg.conn_workers.max(1))
                .map(|_| {
                    let conns = &conns;
                    s.spawn(move || {
                        while let Some(stream) = conns.pop() {
                            // one panicking connection must not take the
                            // worker (and its share of the pool) with it
                            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || self.handle_connection(stream),
                            ));
                            if r.is_err() {
                                self.metrics.inc_worker_panic();
                                crate::info!(
                                    "serve: connection worker panicked; connection dropped, worker continues"
                                );
                            }
                        }
                    })
                })
                .collect();

            self.accept_loop(&conns);
            // drain order matters: connections first (they may still
            // push jobs), then the job queue, then inference
            conns.close();
            for h in conn_handles {
                let _ = h.join();
            }
            self.jobs.close();
            for h in infer_handles {
                let _ = h.join();
            }
        });
        crate::info!("serve: drained and stopped");
        Ok(())
    }

    fn accept_loop(&self, conns: &Queue<TcpStream>) {
        loop {
            if self.shutting_down() {
                return;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // connection sockets are blocking with a short read
                    // timeout so idle keep-alive workers can poll the
                    // shutdown flag
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
                    if conns.push(stream).is_err() {
                        return;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    crate::info!("serve: accept error: {e}");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
    }

    /// Keep-alive request loop for one connection.
    fn handle_connection(&self, stream: TcpStream) {
        // fault point: `panic` rules unwind into the conn worker's
        // catch_unwind; `err` rules just drop the connection (the client
        // sees a reset — exactly the stale-keep-alive race loadgen's
        // single retry covers)
        if crate::util::fault::check("serve.conn.handle").is_err() {
            return;
        }
        let mut conn = HttpConn::new(stream);
        loop {
            match conn.recv(self.cfg.max_body) {
                Ok(Recv::Request(req)) => {
                    let t = Instant::now();
                    let endpoint = endpoint_of(&req);
                    if req.method == "POST" && req.path == "/generate" {
                        // streaming: writes its own close-delimited
                        // response; EOF is the end-of-body marker, so
                        // the connection never goes back to keep-alive
                        let status = self.generate(&req, &mut conn);
                        self.metrics
                            .observe_request(endpoint, status, t.elapsed().as_secs_f64());
                        return;
                    }
                    // during a drain, answer and close
                    let keep = req.keep_alive && !self.shutting_down();
                    let (status, ctype, body, mut extra) = self.route(&req);
                    self.metrics.observe_request(endpoint, status, t.elapsed().as_secs_f64());
                    // every 503 (shed, breaker, draining) is retryable
                    if status == 503 {
                        extra.push(("Retry-After", "1".to_string()));
                    }
                    let sent = if extra.is_empty() {
                        conn.send(status, ctype, &body, keep)
                    } else {
                        conn.send_ext(status, ctype, &extra, &body, keep)
                    };
                    if sent.is_err() || !keep {
                        return;
                    }
                }
                Ok(Recv::Idle) => {
                    if self.shutting_down() {
                        return;
                    }
                }
                Ok(Recv::Eof) => return,
                Err(e) => {
                    // protocol error: answer with its status and close
                    self.metrics.observe_request(Endpoint::Other, e.status, 0.0);
                    let _ =
                        conn.send(e.status, "application/json", error_json(&e.msg).as_bytes(), false);
                    return;
                }
            }
        }
    }

    /// Dispatch one request.  Returns status, content type, body, and
    /// any extra response headers (`/predict` adds `X-Stage-Timings`
    /// when tracing is on; 503s grow `Retry-After` in the caller).
    fn route(&self, req: &Request) -> (u16, &'static str, Vec<u8>, Vec<(&'static str, String)>) {
        if req.method == "POST" && req.path == "/predict" {
            return match self.predict(req) {
                Ok((body, extra)) => (200, "application/json", body, extra),
                Err((status, msg)) => {
                    (status, "application/json", error_json(&msg).into_bytes(), Vec::new())
                }
            };
        }
        let (status, ctype, body) = match (req.method.as_str(), req.path.as_str()) {
            // liveness: answers 200 whenever the process can serve HTTP
            ("GET", "/healthz") => json_ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("models", Json::num(self.registry.len() as f64)),
                ("queue_depth", Json::num(self.jobs.len() as f64)),
                ("max_batch", Json::num(self.cfg.max_batch as f64)),
                ("draining", Json::Bool(self.shutting_down())),
            ])),
            // readiness: 503 while draining; "degraded" (still 200, so
            // in-flight traffic isn't cut) while any breaker is open
            ("GET", "/readyz") => {
                let draining = self.shutting_down();
                let breakers = self.registry.breaker_states();
                let open = breakers.iter().filter(|(_, s)| *s == BREAKER_OPEN).count();
                let state = if draining {
                    "draining"
                } else if open > 0 {
                    "degraded"
                } else {
                    "ok"
                };
                let body = Json::obj(vec![
                    ("status", Json::str(state)),
                    ("ready", Json::Bool(!draining)),
                    ("models", Json::num(self.registry.len() as f64)),
                    ("breakers_open", Json::num(open as f64)),
                    ("queue_depth", Json::num(self.jobs.len() as f64)),
                ]);
                (
                    if draining { 503 } else { 200 },
                    "application/json",
                    body.to_string().into_bytes(),
                )
            }
            ("GET", "/metrics") => (
                200,
                "text/plain; version=0.0.4",
                self.metrics
                    .render(
                        self.jobs.len(),
                        self.registry.len(),
                        &self.registry.breaker_states(),
                    )
                    .into_bytes(),
            ),
            ("GET", "/models") => json_ok(self.registry.describe()),
            // last-N completed /predict stage splits (newest last)
            ("GET", "/debug/trace") => {
                let n = req
                    .query
                    .get("n")
                    .and_then(|s| s.trim().parse::<usize>().ok())
                    .unwrap_or(32)
                    .min(self.cfg.trace_ring.max(1));
                json_ok(self.debug_trace(n))
            }
            // live cluster-health telemetry: per-model gauges harvested
            // from the engine's cluster_stats taps + decode cache state
            ("GET", "/debug/clusters") => json_ok(self.debug_clusters()),
            ("POST", "/models/reload") => match self.reload(req) {
                Ok(body) => (200, "application/json", body),
                Err((status, msg)) => (status, "application/json", error_json(&msg).into_bytes()),
            },
            ("POST", "/admin/shutdown") => {
                crate::info!("serve: shutdown requested via /admin/shutdown");
                self.shutdown.store(true, Ordering::SeqCst);
                json_ok(Json::obj(vec![("ok", Json::Bool(true)), ("draining", Json::Bool(true))]))
            }
            _ => (
                404,
                "application/json",
                error_json(&format!("no endpoint {} {}", req.method, req.path)).into_bytes(),
            ),
        };
        (status, ctype, body, Vec::new())
    }

    /// The `/debug/trace?n=` payload: the newest `n` stage-split rows.
    fn debug_trace(&self, n: usize) -> Json {
        let ring = self.recent.lock().unwrap_or_else(|p| p.into_inner());
        let skip = ring.len().saturating_sub(n);
        Json::obj(vec![
            ("count", Json::num(ring.len().min(n) as f64)),
            ("requests", Json::Arr(ring.iter().skip(skip).map(TraceRow::to_json).collect())),
        ])
    }

    /// Record one completed /predict into the `/debug/trace` ring.
    fn push_trace(&self, model: String, rows: usize, status: u16, stages_us: [u64; 5]) {
        let seq = self.trace_seq.fetch_add(1, Ordering::Relaxed);
        let cap = self.cfg.trace_ring.max(1);
        let mut ring = self.recent.lock().unwrap_or_else(|p| p.into_inner());
        while ring.len() >= cap {
            ring.pop_front();
        }
        ring.push_back(TraceRow { seq, model, rows, status, stages_us });
    }

    /// The `/debug/clusters` payload: whether the stats gate is on, the
    /// per-model cluster-health summaries last harvested into the
    /// metrics table, and the decode cluster-cache counters.
    fn debug_clusters(&self) -> Json {
        let models: Vec<Json> = self
            .metrics
            .cluster_health_snapshot()
            .into_iter()
            .map(|(name, s)| {
                Json::obj(vec![
                    ("model", Json::str(&name)),
                    ("layers", Json::num(s.layers as f64)),
                    ("entropy", Json::num(s.entropy)),
                    ("balance_cv", Json::num(s.balance_cv)),
                    ("churn", Json::num(s.churn)),
                    ("max_fraction", Json::num(s.max_fraction)),
                    ("collapsed_layers", Json::num(s.collapsed_layers as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("enabled", Json::Bool(cluster_stats::active())),
            ("models", Json::Arr(models)),
            (
                "decode_passthrough_tokens",
                Json::num(self.metrics.decode_passthrough_total() as f64),
            ),
        ])
    }

    /// `/predict`: parse → resolve model → enqueue → wait for the demuxed
    /// logits.  Error statuses: 400 malformed, 404 unknown model, 503
    /// draining/breaker-open/deadline-shed, 504 timeout, 500 engine
    /// failure or worker loss.  On success, returns the body plus any
    /// extra headers (`X-Stage-Timings` when tracing is on) and feeds
    /// the stage histograms and the `/debug/trace` ring.
    fn predict(
        &self,
        req: &Request,
    ) -> Result<(Vec<u8>, Vec<(&'static str, String)>), (u16, String)> {
        let t_parse = Instant::now();
        let text = req.body_str().map_err(|e| (e.status, e.msg))?;
        let body = Json::parse(text).map_err(|e| (400, format!("invalid JSON body: {e}")))?;
        let model_name = req
            .query
            .get("model")
            .map(|s| s.as_str())
            .or_else(|| body.get("model").and_then(Json::as_str));
        let entry =
            self.registry.resolve(model_name).map_err(|e| (404, format!("{e:#}")))?;
        // circuit breaker: a model failing consecutively sheds fast
        // instead of queueing more work onto a broken engine
        if !entry.breaker.allow() {
            self.metrics.inc_shed();
            return Err((
                503,
                format!("model {:?} is failing; circuit breaker is open", entry.name),
            ));
        }
        // per-request deadline budget, measured from arrival so queue
        // wait counts against it
        let deadline = match req.headers.get("x-deadline-ms") {
            Some(v) if self.cfg.deadline_ms > 0 => {
                let ms: u64 = v
                    .trim()
                    .parse()
                    .map_err(|_| (400, format!("invalid X-Deadline-Ms {v:?}")))?;
                if ms == 0 {
                    return Err((400, "X-Deadline-Ms must be at least 1".to_string()));
                }
                Some(Instant::now() + Duration::from_millis(ms.min(self.cfg.deadline_ms)))
            }
            _ => None,
        };
        let meta = &entry.manifest.meta;
        if meta.dual {
            return Err((
                400,
                format!("model {:?} is a dual-encoder config; /predict serves single-sequence models", entry.name),
            ));
        }
        // cap rows per request at one micro-batch: keeps a single small
        // body from amplifying into an unbounded padded allocation and
        // preserves the batcher's "batch ≤ max_batch rows" invariant
        let row_cap = self.cfg.max_batch.max(1);
        let rows = parse_token_rows(&body, row_cap)?;
        let n_rows = rows.len();
        let tokens = pad_rows(&rows, meta.seq_len, 0).map_err(|e| (400, format!("{e:#}")))?;

        if self.shutting_down() {
            return Err((503, "server is draining".to_string()));
        }
        let parse_us = t_parse.elapsed().as_micros() as u64;
        let (tx, rx) = sync_channel(1);
        let job = PredictJob {
            entry,
            tokens,
            rows: n_rows,
            reply: tx,
            deadline,
            enqueued: Instant::now(),
            popped: None,
        };
        self.jobs.push(job).map_err(|_| (503, "server is draining".to_string()))?;
        let reply = rx.recv_timeout(PREDICT_TIMEOUT).map_err(|e| match e {
            std::sync::mpsc::RecvTimeoutError::Timeout => {
                (504, "inference timed out".to_string())
            }
            // the job died with a restarted worker before any reply
            std::sync::mpsc::RecvTimeoutError::Disconnected => {
                (500, "inference worker restarted; request was not processed".to_string())
            }
        })?;
        let ok = reply.map_err(|err| match err {
            ReplyErr::Shed(msg) => (503, msg),
            ReplyErr::Engine(msg) => (500, msg),
        })?;

        let t_reply = Instant::now();
        let nc = ok.n_classes;
        let mut logit_rows = Vec::with_capacity(n_rows);
        let mut argmax = Vec::with_capacity(n_rows);
        for r in 0..n_rows {
            let row = &ok.logits[r * nc..(r + 1) * nc];
            let mut arg = 0;
            for (j, &x) in row.iter().enumerate() {
                if x > row[arg] {
                    arg = j;
                }
            }
            argmax.push(arg);
            logit_rows.push(Json::Arr(row.iter().map(|&x| Json::num(x as f64)).collect()));
        }
        let out = Json::obj(vec![
            ("model", Json::str(&ok.model)),
            ("version", Json::num(ok.version as f64)),
            ("rows", Json::num(n_rows as f64)),
            ("logits", Json::Arr(logit_rows)),
            ("argmax", Json::arr_usize(&argmax)),
            ("batch_rows", Json::num(ok.batch_rows as f64)),
        ]);
        let body = out.to_string().into_bytes();

        let reply_us = t_reply.elapsed().as_micros() as u64;
        let stages_us = [parse_us, ok.queue_us, ok.batch_us, ok.compute_us, reply_us];
        self.metrics.observe_stages(stages_us.map(|us| us as f64 / 1e6));
        self.push_trace(ok.model, n_rows, 200, stages_us);
        let mut extra = Vec::new();
        if trace::active() {
            extra.push((
                "X-Stage-Timings",
                format!(
                    "parse={};queue={};batch={};compute={};reply={}",
                    stages_us[0], stages_us[1], stages_us[2], stages_us[3], stages_us[4]
                ),
            ));
        }
        Ok((body, extra))
    }

    /// Parse, admit, and prefill one `/generate` request.  Everything
    /// fallible happens here, before the response head is written, so
    /// every rejection is an ordinary buffered JSON error: 400 malformed
    /// body / undecodable model, 404 unknown model, 503 draining or
    /// breaker-open, 500 prefill failure.
    fn generate_setup(&self, req: &Request) -> Result<GenReady, (u16, String)> {
        let t_parse = Instant::now();
        let text = req.body_str().map_err(|e| (e.status, e.msg))?;
        let body = Json::parse(text).map_err(|e| (400, format!("invalid JSON body: {e}")))?;
        let model_name = req
            .query
            .get("model")
            .map(|s| s.as_str())
            .or_else(|| body.get("model").and_then(Json::as_str));
        let entry =
            self.registry.resolve(model_name).map_err(|e| (404, format!("{e:#}")))?;
        if !entry.breaker.allow() {
            self.metrics.inc_shed();
            return Err((
                503,
                format!("model {:?} is failing; circuit breaker is open", entry.name),
            ));
        }
        // same deadline contract as /predict, measured from arrival —
        // generation stops mid-stream once the budget runs out
        let deadline = match req.headers.get("x-deadline-ms") {
            Some(v) if self.cfg.deadline_ms > 0 => {
                let ms: u64 = v
                    .trim()
                    .parse()
                    .map_err(|_| (400, format!("invalid X-Deadline-Ms {v:?}")))?;
                if ms == 0 {
                    return Err((400, "X-Deadline-Ms must be at least 1".to_string()));
                }
                Some(Instant::now() + Duration::from_millis(ms.min(self.cfg.deadline_ms)))
            }
            _ => None,
        };
        let prompt = body
            .get("prompt")
            .ok_or((400, "body needs a \"prompt\" field".to_string()))?
            .as_arr()
            .ok_or((400, "\"prompt\" must be an array of token ids".to_string()))
            .and_then(parse_row)?;
        if prompt.is_empty() {
            return Err((400, "\"prompt\" is empty".to_string()));
        }
        let vocab = entry.manifest.meta.vocab;
        if let Some(&t) = prompt.iter().find(|&&t| t < 0 || t as usize >= vocab) {
            return Err((400, format!("prompt token {t} outside vocab 0..{vocab}")));
        }
        let max_new = match body.get("max_new_tokens") {
            Some(v) => v
                .as_usize()
                .ok_or((400, "\"max_new_tokens\" must be a non-negative integer".to_string()))?,
            None => 32,
        };
        if max_new == 0 || max_new > MAX_NEW_TOKENS {
            return Err((
                400,
                format!("\"max_new_tokens\" must be in 1..={MAX_NEW_TOKENS}"),
            ));
        }
        let temperature = match body.get("temperature") {
            Some(v) => {
                let t = v.as_f64().ok_or((400, "\"temperature\" must be a number".to_string()))?;
                if !(t.is_finite() && t >= 0.0) {
                    return Err((400, format!("invalid temperature {t}")));
                }
                t as f32
            }
            None => 0.0,
        };
        let seed = match body.get("seed") {
            Some(v) => {
                v.as_usize().ok_or((400, "\"seed\" must be a non-negative integer".to_string()))?
                    as u64
            }
            None => 0,
        };
        if self.shutting_down() {
            return Err((503, "server is draining".to_string()));
        }
        // the decode entry comes from the same engine cache as predict;
        // models without one (non-causal, non-CAST, dual) are rejected
        let exe = self
            .registry
            .engine()
            .load(&entry.manifest, "decode")
            .map_err(|e| (400, format!("model {:?} cannot decode: {e:#}", entry.name)))?;
        let parse_us = t_parse.elapsed().as_micros() as u64;

        // chunked prefill of everything but the last prompt token (the
        // first decode_step input), inside the panic fence
        let t_prefill = Instant::now();
        let mut session = exe.decode_begin().map_err(|e| (500, format!("{e:#}")))?;
        {
            let params: Vec<&HostTensor> = entry.params.iter().collect();
            let (prefix, _) = prompt.split_at(prompt.len() - 1);
            for chunk in prefix.chunks(PREFILL_CHUNK) {
                engine_call(|| exe.decode_prefill(&params, session.as_mut(), chunk)).map_err(
                    |(panicked, msg)| {
                        if panicked {
                            self.metrics.inc_worker_panic();
                        }
                        entry.breaker.record_failure();
                        (500, format!("prefill failed: {msg}"))
                    },
                )?;
            }
        }
        let prefill_us = t_prefill.elapsed().as_micros() as u64;
        let next = *prompt.last().unwrap();
        Ok(GenReady {
            entry,
            exe,
            session,
            next,
            max_new,
            temperature,
            rng: Rng::new(seed),
            deadline,
            parse_us,
            prefill_us,
        })
    }

    /// `POST /generate`: incremental decode streamed as close-delimited
    /// NDJSON — one `{"token":…,"pos":…}` line per generated token as it
    /// is produced, then a final `{"done":…}` summary (or an in-band
    /// `{"error":…}` line if the engine fails mid-stream).  Returns the
    /// status recorded in the request metrics; the per-request
    /// `DecodeState` session lives and dies with this call, so
    /// completion, deadline expiry, and client disconnect all drop it.
    fn generate(&self, req: &Request, conn: &mut HttpConn<TcpStream>) -> u16 {
        let mut ready = match self.generate_setup(req) {
            Ok(r) => r,
            Err((status, msg)) => {
                let mut extra = Vec::new();
                if status == 503 {
                    extra.push(("Retry-After", "1".to_string()));
                }
                let _ = conn.send_ext(
                    status,
                    "application/json",
                    &extra,
                    error_json(&msg).as_bytes(),
                    false,
                );
                return status;
            }
        };
        // the head commits us to 200: from here every failure is
        // reported in-band on the stream
        let mut extra = Vec::new();
        if trace::active() {
            extra.push((
                "X-Stage-Timings",
                format!(
                    "parse={};queue=0;batch=0;compute={};reply=0",
                    ready.parse_us, ready.prefill_us
                ),
            ));
        }
        let w = match conn.start_streaming(200, "application/x-ndjson", &extra) {
            Ok(w) => w,
            Err(_) => return 200, // client went away before the head
        };
        let params: Vec<&HostTensor> = ready.entry.params.iter().collect();
        let t_stream = Instant::now();
        let mut produced = 0usize;
        let mut next = ready.next;
        let mut status = 200;
        let mut stop = "length";
        for _ in 0..ready.max_new {
            if ready.deadline.map(|d| Instant::now() >= d).unwrap_or(false) {
                self.metrics.inc_deadline_exceeded();
                stop = "deadline";
                break;
            }
            let logits =
                match engine_call(|| ready.exe.decode_step(&params, ready.session.as_mut(), next))
                {
                    Ok(l) => l,
                    Err((panicked, msg)) => {
                        if panicked {
                            self.metrics.inc_worker_panic();
                        }
                        ready.entry.breaker.record_failure();
                        let line = Json::obj(vec![
                            ("error", Json::str(&msg)),
                            ("tokens", Json::num(produced as f64)),
                        ]);
                        let _ = write_ndjson_line(w, &line);
                        status = 500; // metrics only — the head already went out as 200
                        stop = "error";
                        break;
                    }
                };
            let tok = decode::sample(&logits, ready.temperature, &mut ready.rng) as i32;
            // session.len() is the history length after absorbing `next`,
            // i.e. the position the predicted token will occupy
            let line = Json::obj(vec![
                ("token", Json::num(tok as f64)),
                ("pos", Json::num(ready.session.len() as f64)),
            ]);
            if write_ndjson_line(w, &line).is_err() {
                stop = "disconnect";
                break;
            }
            produced += 1;
            next = tok;
        }
        if stop != "error" {
            ready.entry.breaker.record_success();
        }
        if stop == "length" || stop == "deadline" {
            let line = Json::obj(vec![
                ("done", Json::Bool(true)),
                ("model", Json::str(&ready.entry.name)),
                ("version", Json::num(ready.entry.version as f64)),
                ("tokens", Json::num(produced as f64)),
                ("stop", Json::str(stop)),
            ]);
            let _ = write_ndjson_line(w, &line);
        }
        let compute_us = ready.prefill_us + t_stream.elapsed().as_micros() as u64;
        let stages_us = [ready.parse_us, 0, 0, compute_us, 0];
        self.metrics.observe_stages(stages_us.map(|us| us as f64 / 1e6));
        self.metrics.observe_generate_tokens(produced);
        // harvest cluster-cache health from the finished session: fill
        // level plus the Nc·κ passthrough counter (ROADMAP dead-end)
        if let Some(st) = ready.session.as_any().downcast_mut::<decode::DecodeState>() {
            let (fill, capacity) = st.cache_fill();
            self.metrics.observe_decode_session(st.passthrough_tokens(), fill, capacity);
        }
        // opportunistically drain any cluster stats the engine
        // accumulated since the last harvest (predict batches running
        // concurrently feed the same accumulator)
        if cluster_stats::active() {
            if let Some(summary) = cluster_stats::take_summary() {
                self.metrics.update_cluster_health(&ready.entry.name, summary);
            }
        }
        self.push_trace(ready.entry.name.clone(), produced, status, stages_us);
        status
    }

    /// `/models/reload?model=NAME`: rebuild the named entry from its
    /// recorded source.  The old snapshot serves until the new one lands.
    fn reload(&self, req: &Request) -> Result<Vec<u8>, (u16, String)> {
        let name = match req.query.get("model") {
            Some(n) => n.clone(),
            None if self.registry.len() == 1 => {
                self.registry.resolve(None).map_err(|e| (500, format!("{e:#}")))?.name.clone()
            }
            None => return Err((400, "reload needs ?model=<name>".to_string())),
        };
        if self.registry.get(&name).is_none() {
            return Err((404, format!("unknown model {name:?} (see /models)")));
        }
        let entry = self.registry.reload(&name).map_err(|e| (500, format!("{e:#}")))?;
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("model", Json::str(&entry.name)),
            ("version", Json::num(entry.version as f64)),
        ])
        .to_string()
        .into_bytes())
    }
}

/// One admitted `/generate` request: model snapshot pinned, prompt
/// prefilled into the decode session, sampler seeded — ready to stream.
struct GenReady {
    entry: Arc<ModelEntry>,
    exe: Arc<dyn Executable>,
    session: Box<dyn DecodeSession>,
    /// Last prompt token — the first `decode_step` input.
    next: i32,
    max_new: usize,
    /// 0 = greedy argmax, > 0 = softmax sampling at this temperature.
    temperature: f32,
    rng: Rng,
    deadline: Option<Instant>,
    parse_us: u64,
    prefill_us: u64,
}

/// Run one decode engine call inside a panic fence so a mid-stream
/// engine panic (fault injection, engine bug) is contained and answered
/// in-band instead of tearing down the connection worker.  `Err` is
/// `(panicked, message)`.
fn engine_call<T>(f: impl FnOnce() -> Result<T>) -> Result<T, (bool, String)> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(e)) => Err((false, format!("{e:#}"))),
        Err(p) => {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err((true, format!("decode engine panicked: {msg}")))
        }
    }
}

/// Write one NDJSON line and flush, so the client sees each token as it
/// is produced rather than on connection close.
fn write_ndjson_line(w: &mut impl Write, line: &Json) -> std::io::Result<()> {
    let mut s = line.to_string();
    s.push('\n');
    w.write_all(s.as_bytes())?;
    w.flush()
}

fn endpoint_of(req: &Request) -> Endpoint {
    match req.path.as_str() {
        "/predict" => Endpoint::Predict,
        "/generate" => Endpoint::Generate,
        "/models" => Endpoint::Models,
        "/models/reload" => Endpoint::Reload,
        "/metrics" => Endpoint::Metrics,
        "/healthz" | "/readyz" => Endpoint::Healthz,
        "/admin/shutdown" => Endpoint::Shutdown,
        "/debug/trace" => Endpoint::DebugTrace,
        "/debug/clusters" => Endpoint::DebugClusters,
        _ => Endpoint::Other,
    }
}

fn json_ok(j: Json) -> (u16, &'static str, Vec<u8>) {
    (200, "application/json", j.to_string().into_bytes())
}

fn error_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// `"tokens"`: one flat row (`[1,2,3]`) or a list of rows
/// (`[[1,2],[3,4]]`), every element an integer in i32 range, at most
/// `row_cap` rows (one micro-batch) per request.
fn parse_token_rows(body: &Json, row_cap: usize) -> Result<Vec<Vec<i32>>, (u16, String)> {
    let toks = body
        .get("tokens")
        .ok_or((400, "body needs a \"tokens\" field".to_string()))?;
    let arr = toks
        .as_arr()
        .ok_or((400, "\"tokens\" must be an array".to_string()))?;
    if arr.is_empty() {
        return Err((400, "\"tokens\" is empty".to_string()));
    }
    let nested = arr[0].as_arr().is_some();
    let mut rows = Vec::new();
    if nested {
        if arr.len() > row_cap {
            return Err((
                400,
                format!("{} token rows exceed the {row_cap}-row per-request cap (--max-batch)", arr.len()),
            ));
        }
        for (i, row) in arr.iter().enumerate() {
            let row = row
                .as_arr()
                .ok_or((400, format!("tokens row {i} is not an array")))?;
            rows.push(parse_row(row)?);
        }
    } else {
        rows.push(parse_row(arr)?);
    }
    Ok(rows)
}

fn parse_row(row: &[Json]) -> Result<Vec<i32>, (u16, String)> {
    let mut out = Vec::with_capacity(row.len());
    for v in row {
        let n = v.as_f64().ok_or((400, "tokens must be integers".to_string()))?;
        if !n.is_finite() || n.fract() != 0.0 || !(-2147483648.0..=2147483647.0).contains(&n) {
            return Err((400, format!("token {n} is not an i32")));
        }
        out.push(n as i32);
    }
    Ok(out)
}

/// One inference worker: form batches, run them, demux.  Scratch is
/// keyed by model snapshot so a reload gets fresh working memory; the
/// map is cleared if it ever grows past a handful of snapshots.
fn infer_loop(
    jobs: Arc<Queue<PredictJob>>,
    max_batch: usize,
    max_wait: Duration,
    metrics: Arc<Metrics>,
) {
    let mut former = BatchFormer::new(jobs, max_batch, max_wait);
    let mut scratches: HashMap<(String, u64), Box<dyn Scratch>> = HashMap::new();
    while let Some(batch) = former.next_batch() {
        let key = (batch[0].entry.name.clone(), batch[0].entry.version);
        if !scratches.contains_key(&key) {
            // a new snapshot of this model (first sight or hot reload):
            // drop only the model's stale versions, keeping every other
            // model's workspace warm — the map stays bounded by the
            // registry's model count
            scratches.retain(|(name, _), _| name != &key.0);
        }
        let scratch = scratches
            .entry(key.clone())
            .or_insert_with(|| batch[0].entry.exe.make_scratch());
        if !run_batch(batch, scratch.as_mut(), &metrics) {
            // the panic may have torn the workspace mid-write; rebuild
            // it fresh before the next batch of this model
            scratches.remove(&key);
        }
    }
}
