//! `cast loadgen`: a closed-loop load-generating client for a running
//! `cast serve` instance.
//!
//! `--conns` workers each hold one keep-alive connection and issue
//! `--requests` sequential `POST /predict` calls (closed loop: the next
//! request leaves only after the previous response lands), so server-side
//! batching opportunity comes purely from *concurrency across
//! connections* — exactly the production shape the micro-batcher
//! targets.  Token payloads are deterministic per (seed, conn, request),
//! so two runs against the same checkpoint are comparable.
//!
//! The report carries client-side truth: exact p50/p99 over every
//! request's wall time and aggregate requests/sec, which `cast loadgen
//! --bench-json` appends to `BENCH_native.json` as a
//! `serve_reqs_per_sec` row (the batched-vs-unbatched acceptance pair).
//!
//! `--client-faults` turns a deterministic residue of each worker's
//! requests into hostile clients: slow-loris bodies (the full request
//! dribbled out in delayed chunks) and mid-body disconnects (full
//! `Content-Length` declared, half the body sent, socket dropped).  The
//! report counts how many of those the server shed cleanly — an orderly
//! HTTP response or close for the slow-loris, a 200 `/healthz` probe on
//! a fresh connection right after each disconnect — and `cast loadgen`
//! fails if any fault was shed uncleanly.

use std::io::{self, ErrorKind};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::parallel;
use crate::util::rng::Rng;

use super::http;

#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub addr: String,
    /// Concurrent connections (each a closed loop).
    pub conns: usize,
    /// Requests per connection.
    pub requests: usize,
    /// Model to drive (default: the server's first model).
    pub model: Option<String>,
    /// Tokens per request (default: the model's full sequence length;
    /// shorter values exercise the padding path).
    pub seq: Option<usize>,
    /// Drive `POST /generate` (streaming, close-delimited) instead of
    /// `/predict`, generating this many tokens per request.  Each request
    /// uses a fresh connection — the streaming protocol closes it.
    pub generate: Option<usize>,
    pub seed: u64,
    /// Inject client-side faults (slow-loris bodies, mid-body
    /// disconnects) on a deterministic residue of requests and verify
    /// the server sheds them cleanly.
    pub client_faults: bool,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            addr: "127.0.0.1:8477".to_string(),
            conns: 16,
            requests: 25,
            model: None,
            seq: None,
            generate: None,
            seed: 0,
            client_faults: false,
        }
    }
}

#[derive(Clone, Debug)]
pub struct LoadReport {
    pub model: String,
    /// Tokens per request actually sent.
    pub seq_len: usize,
    pub conns: usize,
    /// Successful requests.
    pub ok: usize,
    /// Failed requests (non-200 or transport errors) — the sum of the
    /// `err_*` classes below.
    pub errors: usize,
    /// Stale keep-alive connections retried exactly once (a retry that
    /// then succeeds counts in `ok`, not `errors`).
    pub retried: usize,
    /// Connect failures (server unreachable when a worker reconnects).
    pub err_connect: usize,
    /// Reset/EOF of a reused connection that failed even after the retry.
    pub err_stale: usize,
    /// Served non-200 responses.
    pub err_status: usize,
    /// Other transport errors (reset mid-exchange on a fresh connection,
    /// malformed response, ...).
    pub err_transport: usize,
    pub elapsed_s: f64,
    pub reqs_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// The server's `--max-batch` (from /healthz) — labels the bench
    /// row so the batched/unbatched acceptance pair is attributable.
    pub server_max_batch: usize,
    /// Largest micro-batch any response reported riding in (observed
    /// proof that coalescing actually happened).
    pub batch_rows_max: usize,
    /// Responses that carried an `X-Stage-Timings` header (the server
    /// emits it only while tracing is enabled; 0 otherwise).
    pub staged: usize,
    /// Mean server-side queue wait over staged responses, ms — the
    /// client-observed queue-vs-compute split.
    pub stage_queue_ms: f64,
    /// Mean server-side compute (shared forward) over staged responses, ms.
    pub stage_compute_ms: f64,
    /// Slow-loris faults injected (`--client-faults`); 0 otherwise.
    pub faults_slowloris: usize,
    /// Mid-body-disconnect faults injected (`--client-faults`).
    pub faults_disconnect: usize,
    /// Faults the server shed cleanly: an orderly HTTP response or
    /// close for a slow-loris, a 200 `/healthz` on a fresh connection
    /// right after a disconnect.  Fault requests never count in
    /// `ok`/`errors` or the latency percentiles.
    pub faults_shed: usize,
}

/// Ask the server what it serves and pick the target model.
/// Returns `(name, request_seq_len, vocab, server_max_batch)`.
fn discover(cfg: &LoadgenConfig) -> Result<(String, usize, usize, usize)> {
    let mut stream = TcpStream::connect(cfg.addr.as_str())
        .with_context(|| format!("connecting to {} (is `cast serve` running?)", cfg.addr))?;
    let mut carry = Vec::new();
    http::write_request(&mut stream, "GET", "/models", b"")?;
    let resp = http::read_response(&mut stream, &mut carry, http::CLIENT_MAX_BODY)?;
    anyhow::ensure!(resp.status == 200, "GET /models returned {}", resp.status);
    let body = Json::parse(std::str::from_utf8(&resp.body)?)
        .map_err(|e| anyhow::anyhow!("bad /models JSON: {e}"))?;
    let models = body.get("models").and_then(Json::as_arr).context("/models payload")?;
    let picked = match &cfg.model {
        Some(name) => models
            .iter()
            .find(|m| m.get("name").and_then(Json::as_str) == Some(name))
            .with_context(|| format!("server has no model {name:?}"))?,
        None => models.first().context("server has no models loaded")?,
    };
    let name = picked.get("name").and_then(Json::as_str).context("model name")?.to_string();
    let model_seq = picked.get("seq_len").and_then(Json::as_usize).context("model seq_len")?;
    let vocab = picked.get("vocab").and_then(Json::as_usize).unwrap_or(64).max(2);
    let seq = cfg.seq.unwrap_or(model_seq).min(model_seq).max(1);
    // same keep-alive connection: the server's batching config
    http::write_request(&mut stream, "GET", "/healthz", b"")?;
    let health = http::read_response(&mut stream, &mut carry, http::CLIENT_MAX_BODY)?;
    let max_batch = Json::parse(std::str::from_utf8(&health.body).unwrap_or(""))
        .ok()
        .and_then(|h| h.get("max_batch").and_then(Json::as_usize))
        .unwrap_or(0);
    Ok((name, seq, vocab, max_batch))
}

/// Deterministic request body for (seed, conn, request index).
fn request_body(model: &str, rng: &mut Rng, seq: usize, vocab: usize) -> String {
    let tokens: Vec<usize> = (0..seq).map(|_| rng.below(vocab)).collect();
    Json::obj(vec![
        ("model", Json::str(model)),
        ("tokens", Json::Arr(vec![Json::arr_usize(&tokens)])),
    ])
    .to_string()
}

/// Deterministic `/generate` body: a prompt plus the generation budget.
fn generate_body(model: &str, rng: &mut Rng, seq: usize, vocab: usize, max_new: usize) -> String {
    let prompt: Vec<usize> = (0..seq).map(|_| rng.below(vocab)).collect();
    Json::obj(vec![
        ("model", Json::str(model)),
        ("prompt", Json::arr_usize(&prompt)),
        ("max_new_tokens", Json::Num(max_new as f64)),
    ])
    .to_string()
}

/// Whether a 200 streaming `/generate` body actually finished: the last
/// NDJSON line must be the `"done"` summary, not a mid-stream `"error"`
/// (the status line is long gone by the time a step can fail).
fn stream_completed(body: &[u8]) -> bool {
    let text = String::from_utf8_lossy(body);
    let Some(last) = text.lines().rev().find(|l| !l.trim().is_empty()) else {
        return false;
    };
    match Json::parse(last) {
        Ok(j) => j.get("done").is_some() && j.get("error").is_none(),
        Err(_) => false,
    }
}

/// Run the closed loop and aggregate the report.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    let (model, seq, vocab, server_max_batch) = discover(cfg)?;
    let conns = cfg.conns.max(1);
    let per_conn = cfg.requests.max(1);
    crate::info!(
        "loadgen: {} conns x {} reqs -> {} (model {:?}, {} tokens/req)",
        conns,
        per_conn,
        cfg.addr,
        model,
        seq
    );

    let latencies_ms: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(conns * per_conn));
    let retried = AtomicUsize::new(0);
    let err_connect = AtomicUsize::new(0);
    let err_stale = AtomicUsize::new(0);
    let err_status = AtomicUsize::new(0);
    let err_transport = AtomicUsize::new(0);
    let batch_rows_max = AtomicUsize::new(0);
    let faults_slowloris = AtomicUsize::new(0);
    let faults_disconnect = AtomicUsize::new(0);
    let faults_shed = AtomicUsize::new(0);
    let staged = AtomicUsize::new(0);
    let queue_us_sum = AtomicU64::new(0);
    let compute_us_sum = AtomicU64::new(0);
    let t0 = Instant::now();
    parallel::scoped_workers(conns, |w| {
        let connect = || {
            TcpStream::connect(cfg.addr.as_str()).map(|s| {
                let _ = s.set_nodelay(true);
                s
            })
        };
        let mut stream = connect().ok();
        // whether the current connection has served no request yet — a
        // failure on a *reused* connection may be the stale keep-alive
        // race; a failure on a fresh one is a real error
        let mut fresh = true;
        // per-connection carry-over buffer: bytes a read pulls in past
        // one response's body belong to the next response on the same
        // stream, so the buffer lives exactly as long as the connection
        let mut carry: Vec<u8> = Vec::new();
        let mut rng = Rng::new(cfg.seed).split(w as u64);
        let mut local = Vec::with_capacity(per_conn);
        for i in 0..per_conn {
            let Some(s) = stream.as_mut() else {
                // reconnect after a transport error so one dropped
                // connection costs one request, not the whole tail
                err_connect.fetch_add(1, Ordering::Relaxed);
                stream = connect().ok();
                carry.clear();
                fresh = true;
                continue;
            };
            let (target, body) = match cfg.generate {
                Some(max_new) => {
                    ("/generate", generate_body(&model, &mut rng, seq, vocab, max_new))
                }
                None => ("/predict", request_body(&model, &mut rng, seq, vocab)),
            };
            let streaming = cfg.generate.is_some();
            let read = |s: &mut TcpStream, carry: &mut Vec<u8>| {
                if streaming {
                    http::read_response_streaming(s, carry, http::CLIENT_MAX_BODY)
                } else {
                    http::read_response(s, carry, http::CLIENT_MAX_BODY)
                }
            };
            // client-side fault injection: deterministic request-index
            // residues pick the victims, so two runs against the same
            // server inject the same hostility in the same order
            if cfg.client_faults && i % 5 == 1 {
                // slow-loris: identical bytes to a normal request,
                // dribbled out in delayed chunks.  Clean shed = an
                // orderly HTTP response (any status) or an orderly
                // server-side close — never a hang or a poisoned parse.
                faults_slowloris.fetch_add(1, Ordering::Relaxed);
                let r = http::write_request_slowly(
                    s,
                    "POST",
                    target,
                    body.as_bytes(),
                    4,
                    std::time::Duration::from_millis(20),
                )
                .and_then(|()| read(s, &mut carry));
                match r {
                    Ok(_) if !streaming => {
                        faults_shed.fetch_add(1, Ordering::Relaxed);
                        fresh = false;
                    }
                    Ok(_) => {
                        faults_shed.fetch_add(1, Ordering::Relaxed);
                        stream = connect().ok();
                        carry.clear();
                        fresh = true;
                    }
                    Err(ref e) if is_stale_conn(e) => {
                        faults_shed.fetch_add(1, Ordering::Relaxed);
                        stream = connect().ok();
                        carry.clear();
                        fresh = true;
                    }
                    Err(_) => {
                        stream = connect().ok();
                        carry.clear();
                        fresh = true;
                    }
                }
                continue;
            }
            if cfg.client_faults && i % 5 == 3 {
                // mid-body disconnect: declare the full Content-Length,
                // send half the body, drop the socket.  The shed probe
                // is a 200 /healthz on a *fresh* connection — the
                // server must bury the carcass without its other lanes
                // noticing.
                faults_disconnect.fetch_add(1, Ordering::Relaxed);
                let _ = http::write_request_truncated(
                    s,
                    "POST",
                    target,
                    body.as_bytes(),
                    body.len() / 2,
                );
                stream = connect().ok();
                carry.clear();
                fresh = true;
                if let Some(s2) = stream.as_mut() {
                    let probe = http::write_request(s2, "GET", "/healthz", b"")
                        .and_then(|()| {
                            http::read_response(s2, &mut carry, http::CLIENT_MAX_BODY)
                        });
                    match probe {
                        Ok(r) if r.status == 200 => {
                            faults_shed.fetch_add(1, Ordering::Relaxed);
                            fresh = false;
                        }
                        _ => {
                            stream = connect().ok();
                            carry.clear();
                            fresh = true;
                        }
                    }
                }
                continue;
            }
            let t = Instant::now();
            let mut result = http::write_request(s, "POST", target, body.as_bytes())
                .and_then(|()| read(s, &mut carry));
            // a reused keep-alive connection can lose the race with a
            // server-side idle close: the request lands on a dead socket
            // and surfaces as ECONNRESET/EPIPE or an immediate EOF.
            // That exact failure is retried once on a fresh connection;
            // a genuinely failing server still errors out.
            if !fresh && result.as_ref().err().is_some_and(is_stale_conn) {
                retried.fetch_add(1, Ordering::Relaxed);
                stream = connect().ok();
                carry.clear();
                fresh = true;
                match stream.as_mut() {
                    Some(s2) => {
                        result = http::write_request(s2, "POST", target, body.as_bytes())
                            .and_then(|()| read(s2, &mut carry));
                    }
                    None => {
                        err_connect.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                }
            }
            match result {
                Ok(r) if r.status == 200 && streaming && !stream_completed(&r.body) => {
                    // the stream opened but died mid-generation (the
                    // status was already on the wire) — a served error
                    err_status.fetch_add(1, Ordering::Relaxed);
                    stream = connect().ok();
                    carry.clear();
                    fresh = true;
                }
                Ok(r) if r.status == 200 => {
                    fresh = false;
                    local.push(t.elapsed().as_secs_f64() * 1e3);
                    // observed coalescing: the batch this reply rode in
                    if let Some(rows) = Json::parse(std::str::from_utf8(&r.body).unwrap_or(""))
                        .ok()
                        .and_then(|j| j.get("batch_rows").and_then(Json::as_usize))
                    {
                        batch_rows_max.fetch_max(rows, Ordering::Relaxed);
                    }
                    // server-side stage split, present iff tracing is on
                    if let Some((q_us, c_us)) =
                        r.headers.get("x-stage-timings").and_then(|v| parse_stage_header(v))
                    {
                        staged.fetch_add(1, Ordering::Relaxed);
                        queue_us_sum.fetch_add(q_us, Ordering::Relaxed);
                        compute_us_sum.fetch_add(c_us, Ordering::Relaxed);
                    }
                    if streaming {
                        // the server closes every /generate stream
                        stream = connect().ok();
                        carry.clear();
                        fresh = true;
                    }
                }
                Ok(_) => {
                    // a served non-200 — the connection is still good
                    // (unless this was a close-delimited stream)
                    err_status.fetch_add(1, Ordering::Relaxed);
                    if streaming {
                        stream = connect().ok();
                        carry.clear();
                        fresh = true;
                    } else {
                        fresh = false;
                    }
                }
                Err(e) => {
                    let class =
                        if is_stale_conn(&e) { &err_stale } else { &err_transport };
                    class.fetch_add(1, Ordering::Relaxed);
                    stream = connect().ok();
                    carry.clear();
                    fresh = true;
                }
            }
        }
        latencies_ms.lock().unwrap_or_else(|p| p.into_inner()).extend(local);
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let mut lats = latencies_ms.into_inner().unwrap_or_else(|p| p.into_inner());
    lats.sort_by(|a, b| a.total_cmp(b));
    let ok = lats.len();
    let (err_connect, err_stale, err_status, err_transport) = (
        err_connect.load(Ordering::Relaxed),
        err_stale.load(Ordering::Relaxed),
        err_status.load(Ordering::Relaxed),
        err_transport.load(Ordering::Relaxed),
    );
    Ok(LoadReport {
        model,
        seq_len: seq,
        conns,
        ok,
        errors: err_connect + err_stale + err_status + err_transport,
        retried: retried.load(Ordering::Relaxed),
        err_connect,
        err_stale,
        err_status,
        err_transport,
        elapsed_s: elapsed,
        reqs_per_sec: ok as f64 / elapsed.max(1e-9),
        p50_ms: percentile(&lats, 0.50),
        p99_ms: percentile(&lats, 0.99),
        server_max_batch,
        batch_rows_max: batch_rows_max.load(Ordering::Relaxed),
        staged: staged.load(Ordering::Relaxed),
        stage_queue_ms: stage_mean_ms(&queue_us_sum, &staged),
        stage_compute_ms: stage_mean_ms(&compute_us_sum, &staged),
        faults_slowloris: faults_slowloris.load(Ordering::Relaxed),
        faults_disconnect: faults_disconnect.load(Ordering::Relaxed),
        faults_shed: faults_shed.load(Ordering::Relaxed),
    })
}

/// Mean of a µs sum over `n` staged responses, in ms (0 when none).
fn stage_mean_ms(sum_us: &AtomicU64, n: &AtomicUsize) -> f64 {
    let n = n.load(Ordering::Relaxed);
    if n == 0 {
        return 0.0;
    }
    sum_us.load(Ordering::Relaxed) as f64 / 1e3 / n as f64
}

/// Parse an `X-Stage-Timings` value
/// (`parse=..;queue=..;batch=..;compute=..;reply=..`, all µs) into the
/// `(queue, compute)` pair the report aggregates.  `None` on any
/// malformed field — a wire-corrupted header must not skew means.
fn parse_stage_header(v: &str) -> Option<(u64, u64)> {
    let mut queue = None;
    let mut compute = None;
    for part in v.split(';') {
        let (k, val) = part.split_once('=')?;
        let n: u64 = val.trim().parse().ok()?;
        match k.trim() {
            "queue" => queue = Some(n),
            "compute" => compute = Some(n),
            _ => {}
        }
    }
    Some((queue?, compute?))
}

/// The stale keep-alive signature: the connection died without a
/// response byte.  Safe to retry (`/predict` is deterministic and
/// side-effect free); anything else is surfaced as-is.
fn is_stale_conn(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        ErrorKind::ConnectionReset
            | ErrorKind::ConnectionAborted
            | ErrorKind::BrokenPipe
            | ErrorKind::UnexpectedEof
    )
}

/// Nearest-rank percentile over an ascending-sorted sample (0 when empty).
fn percentile(sorted_asc: &[f64], q: f64) -> f64 {
    if sorted_asc.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted_asc.len() as f64).ceil() as usize;
    sorted_asc[rank.clamp(1, sorted_asc.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.50), 50.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn stale_classification_matches_the_dead_socket_kinds() {
        for kind in [
            ErrorKind::ConnectionReset,
            ErrorKind::ConnectionAborted,
            ErrorKind::BrokenPipe,
            ErrorKind::UnexpectedEof,
        ] {
            assert!(is_stale_conn(&io::Error::new(kind, "x")), "{kind:?}");
        }
        for kind in [ErrorKind::InvalidData, ErrorKind::TimedOut, ErrorKind::ConnectionRefused] {
            assert!(!is_stale_conn(&io::Error::new(kind, "x")), "{kind:?}");
        }
    }

    #[test]
    fn stage_header_parses_and_rejects_malformed() {
        assert_eq!(
            parse_stage_header("parse=12;queue=340;batch=90;compute=1800;reply=8"),
            Some((340, 1800))
        );
        assert_eq!(parse_stage_header("queue=1;compute=2"), Some((1, 2)));
        assert_eq!(parse_stage_header("queue=1"), None, "compute missing");
        assert_eq!(parse_stage_header("queue=x;compute=2"), None, "non-numeric");
        assert_eq!(parse_stage_header("garbage"), None);
    }

    #[test]
    fn request_body_is_deterministic_per_stream() {
        let mut a = Rng::new(1).split(0);
        let mut b = Rng::new(1).split(0);
        assert_eq!(request_body("m", &mut a, 8, 16), request_body("m", &mut b, 8, 16));
        let mut c = Rng::new(1).split(1);
        assert_ne!(request_body("m", &mut a, 8, 16), request_body("m", &mut c, 8, 16));
    }
}
