//! Minimal HTTP/1.1 substrate (hyper/axum are unavailable offline).
//!
//! Exactly the subset the serve subsystem needs: an incremental request
//! parser that survives split reads and read timeouts (`HttpConn::recv`
//! buffers partial bytes and reports `Idle` so the connection workers
//! can poll the shutdown flag), keep-alive with pipelining, fixed
//! `Content-Length` bodies (no chunked transfer), a response writer,
//! and the client-side request writer / response reader the loadgen
//! client and the integration tests share.
//!
//! Errors carry the HTTP status they map to, so the connection worker
//! can answer a malformed request (bad method, oversized body, garbage
//! content-length) with the right code instead of dropping the socket.

use std::collections::BTreeMap;
use std::fmt;
use std::io::{self, ErrorKind, Read, Write};

/// Cap on the request line + headers (431 beyond this).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on the header count (431 beyond this).
const MAX_HEADERS: usize = 100;

/// A protocol-level error with the status code it maps to.
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    fn new(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError { status, msg: msg.into() }
    }
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}", self.status, self.msg)
    }
}

impl std::error::Error for HttpError {}

/// One parsed request.  Header names are lowercased; the query string is
/// split off `path` and percent-decoded.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    /// Whether the client expects the connection to stay open (HTTP/1.1
    /// default, overridable via the `Connection` header).
    pub keep_alive: bool,
}

impl Request {
    /// Body as UTF-8 text (400-mapped error otherwise).
    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::new(400, "request body is not valid UTF-8"))
    }
}

/// Outcome of one [`HttpConn::recv`] attempt.
#[derive(Debug)]
pub enum Recv {
    /// A complete request was parsed.
    Request(Request),
    /// The read timed out with no complete request buffered — poll the
    /// shutdown flag and call `recv` again.
    Idle,
    /// The peer closed the connection cleanly between requests.
    Eof,
}

/// One server-side connection: a stream plus the carry-over buffer that
/// makes split reads and pipelined keep-alive requests work.  Generic
/// over the stream so the parser unit tests drive it with in-memory
/// fakes; the server instantiates it with `TcpStream`.
pub struct HttpConn<S: Read + Write> {
    stream: S,
    buf: Vec<u8>,
    /// Head parsed while the body is still arriving — parsed exactly
    /// once per request, surviving timeouts (`Idle`) in between.
    pending: Option<Pending>,
}

struct Pending {
    head: Head,
    body_start: usize,
    total: usize,
}

impl<S: Read + Write> HttpConn<S> {
    pub fn new(stream: S) -> HttpConn<S> {
        HttpConn { stream, buf: Vec::new(), pending: None }
    }

    /// Try to read one complete request.  Loops over reads internally;
    /// returns `Idle` when the underlying stream times out (the server
    /// sets a read timeout so shutdown stays responsive).
    pub fn recv(&mut self, max_body: usize) -> Result<Recv, HttpError> {
        loop {
            if self.pending.is_none() {
                if let Some(head_end) = find_head_end(&self.buf) {
                    let head = parse_head(&self.buf[..head_end])?;
                    let clen = content_length(&head.headers, max_body)?;
                    self.pending =
                        Some(Pending { head, body_start: head_end + 4, total: head_end + 4 + clen });
                } else if self.buf.len() > MAX_HEAD_BYTES {
                    return Err(HttpError::new(431, "request head too large"));
                }
            }
            if let Some(p) = &self.pending {
                if self.buf.len() >= p.total {
                    let p = self.pending.take().unwrap();
                    let body = self.buf[p.body_start..p.total].to_vec();
                    self.buf.drain(..p.total);
                    let h = p.head;
                    return Ok(Recv::Request(Request {
                        method: h.method,
                        path: h.path,
                        query: h.query,
                        headers: h.headers,
                        body,
                        keep_alive: h.keep_alive,
                    }));
                }
            }
            let mut tmp = [0u8; 4096];
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    return if self.buf.is_empty() {
                        Ok(Recv::Eof)
                    } else {
                        Err(HttpError::new(400, "connection closed mid-request"))
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    return Ok(Recv::Idle);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(HttpError::new(400, format!("read error: {e}"))),
            }
        }
    }

    /// Write one response.
    pub fn send(
        &mut self,
        status: u16,
        content_type: &str,
        body: &[u8],
        keep_alive: bool,
    ) -> io::Result<()> {
        write_response(&mut self.stream, status, content_type, body, keep_alive)
    }

    /// Write one response with extra headers (e.g. `Retry-After` on 503).
    pub fn send_ext(
        &mut self,
        status: u16,
        content_type: &str,
        extra: &[(&str, String)],
        body: &[u8],
        keep_alive: bool,
    ) -> io::Result<()> {
        write_response_ext(&mut self.stream, status, content_type, extra, body, keep_alive)
    }

    /// Begin a close-delimited streaming response: write the head (no
    /// `Content-Length`, `Connection: close`) and hand back the raw
    /// stream for incremental body writes.  EOF is the only end-of-body
    /// marker, so the caller must drop the connection when done — the
    /// companion client reader is [`read_response_streaming`].
    pub fn start_streaming(
        &mut self,
        status: u16,
        content_type: &str,
        extra: &[(&str, String)],
    ) -> io::Result<&mut S> {
        write_streaming_head(&mut self.stream, status, content_type, extra)?;
        Ok(&mut self.stream)
    }
}

/// Index of `\r\n\r\n` (start of the terminator) in `buf`, if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

struct Head {
    method: String,
    path: String,
    query: BTreeMap<String, String>,
    headers: BTreeMap<String, String>,
    keep_alive: bool,
}

fn parse_head(head: &[u8]) -> Result<Head, HttpError> {
    let text = std::str::from_utf8(head)
        .map_err(|_| HttpError::new(400, "request head is not valid UTF-8"))?;
    let mut lines = text.split("\r\n");
    let rline = lines.next().unwrap_or("");
    let parts: Vec<&str> = rline.split(' ').collect();
    if parts.len() != 3 {
        return Err(HttpError::new(400, format!("malformed request line {rline:?}")));
    }
    let (method, target, version) = (parts[0], parts[1], parts[2]);
    if !matches!(version, "HTTP/1.1" | "HTTP/1.0") {
        return Err(HttpError::new(400, format!("unsupported protocol {version:?}")));
    }
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, format!("malformed method {method:?}")));
    }
    if !matches!(method, "GET" | "POST") {
        return Err(HttpError::new(405, format!("method {method} not allowed")));
    }

    let mut headers = BTreeMap::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, format!("malformed header line {line:?}")))?;
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        if headers.len() > MAX_HEADERS {
            return Err(HttpError::new(431, "too many headers"));
        }
    }
    if headers.get("transfer-encoding").map(|v| v.to_ascii_lowercase()) == Some("chunked".into()) {
        return Err(HttpError::new(501, "chunked transfer encoding not supported"));
    }

    let keep_alive = match headers.get("connection").map(|v| v.to_ascii_lowercase()) {
        Some(v) if v.contains("close") => false,
        Some(v) if v.contains("keep-alive") => true,
        _ => version == "HTTP/1.1",
    };

    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    let mut query = BTreeMap::new();
    if let Some(q) = raw_query {
        for pair in q.split('&').filter(|p| !p.is_empty()) {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            query.insert(percent_decode(k), percent_decode(v));
        }
    }

    Ok(Head {
        method: method.to_string(),
        path: percent_decode(raw_path),
        query,
        headers,
        keep_alive,
    })
}

fn content_length(headers: &BTreeMap<String, String>, max_body: usize) -> Result<usize, HttpError> {
    let Some(v) = headers.get("content-length") else {
        return Ok(0);
    };
    let n: usize = v
        .trim()
        .parse()
        .map_err(|_| HttpError::new(400, format!("invalid content-length {v:?}")))?;
    if n > max_body {
        return Err(HttpError::new(413, format!("body of {n} bytes exceeds the {max_body}-byte cap")));
    }
    Ok(n)
}

/// Decode `%XX` escapes and `+`-as-space.  Invalid escapes pass through
/// literally (query keys here are model names; strictness buys nothing).
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() => {
                let hex = std::str::from_utf8(&bytes[i + 1..i + 3]).ok();
                match hex.and_then(|h| u8::from_str_radix(h, 16).ok()) {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Response",
    }
}

/// Write one HTTP/1.1 response with a fixed-length body.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    write_response_ext(w, status, content_type, &[], body, keep_alive)
}

/// [`write_response`] plus arbitrary extra headers (name, value).
pub fn write_response_ext(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra: &[(&str, String)],
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {conn}\r\n",
        reason(status),
        body.len()
    )?;
    for (name, value) in extra {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Write the head of a close-delimited streaming response.  No
/// `Content-Length` is emitted and the connection is marked `close`:
/// the body is whatever bytes follow until EOF, which lets the server
/// flush tokens as they are produced (`/generate`).
pub fn write_streaming_head(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra: &[(&str, String)],
) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nConnection: close\r\n",
        reason(status)
    )?;
    for (name, value) in extra {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Write one client request with a fixed-length body (the loadgen /
/// integration-test side of the wire).
pub fn write_request(
    w: &mut impl Write,
    method: &str,
    target: &str,
    body: &[u8],
) -> io::Result<()> {
    write!(
        w,
        "{method} {target} HTTP/1.1\r\nHost: cast-serve\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()
}

/// [`write_request`], except the body dribbles out in `chunks` pieces
/// with a `delay` sleep (and flush) between them — the slow-loris fault
/// `cast loadgen --client-faults` injects.  The bytes on the wire are
/// identical to a normal request; only their timing differs, so a
/// server that tolerates split reads serves it and one with a body
/// deadline sheds it — either way without poisoning the connection
/// state machine.
pub fn write_request_slowly(
    w: &mut impl Write,
    method: &str,
    target: &str,
    body: &[u8],
    chunks: usize,
    delay: std::time::Duration,
) -> io::Result<()> {
    write!(
        w,
        "{method} {target} HTTP/1.1\r\nHost: cast-serve\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )?;
    w.flush()?;
    let step = body.len().div_ceil(chunks.max(1)).max(1);
    for piece in body.chunks(step) {
        std::thread::sleep(delay);
        w.write_all(piece)?;
        w.flush()?;
    }
    Ok(())
}

/// Write the head with a full `Content-Length` declaration but only the
/// first `n` body bytes — the mid-body-disconnect fault.  The caller
/// drops the stream immediately after; the server sees EOF mid-request
/// and must shed the carcass (400 path) without disturbing its other
/// connections.
pub fn write_request_truncated(
    w: &mut impl Write,
    method: &str,
    target: &str,
    body: &[u8],
    n: usize,
) -> io::Result<()> {
    write!(
        w,
        "{method} {target} HTTP/1.1\r\nHost: cast-serve\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )?;
    w.write_all(&body[..n.min(body.len())])?;
    w.flush()
}

/// One parsed client-side response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

/// Default client-side body cap, mirroring the server's `ServeConfig`
/// default: a response claiming more than this is a protocol error, not
/// an allocation request.
pub const CLIENT_MAX_BODY: usize = 8 * 1024 * 1024;

fn bad(msg: String) -> io::Error {
    io::Error::new(ErrorKind::InvalidData, msg)
}

/// Read into `buf` until it holds a complete head; returns the parsed
/// status + headers and the head-terminator index.
fn read_response_head(
    r: &mut impl Read,
    buf: &mut Vec<u8>,
) -> io::Result<(u16, BTreeMap<String, String>, usize)> {
    let head_end = loop {
        if let Some(e) = find_head_end(buf) {
            break e;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(bad("response head too large".into()));
        }
        let mut tmp = [0u8; 4096];
        match r.read(&mut tmp) {
            // EOF before any byte arrives is the stale keep-alive race
            // (server closed an idle connection under us) — surface it
            // with a kind clients can classify for a safe retry
            Ok(0) if buf.is_empty() => {
                return Err(io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed before response head",
                ))
            }
            Ok(0) => return Err(bad("connection closed mid-head".into())),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    };
    let text =
        std::str::from_utf8(&buf[..head_end]).map_err(|_| bad("non-UTF-8 head".into()))?;
    let mut lines = text.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.splitn(3, ' ');
    let proto = parts.next().unwrap_or("");
    if !proto.starts_with("HTTP/1.") {
        return Err(bad("malformed status line".into()));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("malformed status code".into()))?;
    let mut headers = BTreeMap::new();
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }
    Ok((status, headers, head_end))
}

/// The response's declared body length: absent → 0, unparseable or over
/// `max_body` → classified `InvalidData` (mirroring the server's own
/// `content_length` checks — a garbage or hostile length must fail, not
/// silently read 0 or allocate unboundedly).
fn response_content_length(
    headers: &BTreeMap<String, String>,
    max_body: usize,
) -> io::Result<usize> {
    let Some(v) = headers.get("content-length") else {
        return Ok(0);
    };
    let n: usize = v
        .trim()
        .parse()
        .map_err(|_| bad(format!("invalid response content-length {v:?}")))?;
    if n > max_body {
        return Err(bad(format!(
            "response body of {n} bytes exceeds the {max_body}-byte cap"
        )));
    }
    Ok(n)
}

/// Blocking read of exactly one fixed-length response (status line,
/// headers, `Content-Length` body).  `carry` is the connection's
/// carry-over buffer: any bytes past this response's body (a pipelined
/// follow-up already in flight) stay buffered there for the next call
/// instead of being dropped on the floor — callers keep one `Vec` per
/// connection and thread it through every read on that stream.
pub fn read_response(
    r: &mut impl Read,
    carry: &mut Vec<u8>,
    max_body: usize,
) -> io::Result<Response> {
    let mut buf = std::mem::take(carry);
    let (status, headers, head_end) = read_response_head(r, &mut buf)?;
    let clen = response_content_length(&headers, max_body)?;
    let body_start = head_end + 4;
    while buf.len() < body_start + clen {
        let mut tmp = [0u8; 4096];
        match r.read(&mut tmp) {
            Ok(0) => return Err(bad("connection closed mid-body".into())),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    *carry = buf.split_off(body_start + clen);
    let body = buf.split_off(body_start);
    Ok(Response { status, headers, body })
}

/// Blocking read of one **close-delimited** response — the `/generate`
/// streaming wire format: no `Content-Length`, `Connection: close`, body
/// runs until EOF (capped at `max_body`).  A response that does declare
/// a length (the pre-stream error path) is completed normally instead.
pub fn read_response_streaming(
    r: &mut impl Read,
    carry: &mut Vec<u8>,
    max_body: usize,
) -> io::Result<Response> {
    let mut buf = std::mem::take(carry);
    let (status, headers, head_end) = read_response_head(r, &mut buf)?;
    let body_start = head_end + 4;
    if headers.contains_key("content-length") {
        let clen = response_content_length(&headers, max_body)?;
        while buf.len() < body_start + clen {
            let mut tmp = [0u8; 4096];
            match r.read(&mut tmp) {
                Ok(0) => return Err(bad("connection closed mid-body".into())),
                Ok(n) => buf.extend_from_slice(&tmp[..n]),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        *carry = buf.split_off(body_start + clen);
        let body = buf.split_off(body_start);
        return Ok(Response { status, headers, body });
    }
    let mut body = buf.split_off(body_start);
    loop {
        if body.len() > max_body {
            return Err(bad(format!("streamed body exceeds the {max_body}-byte cap")));
        }
        let mut tmp = [0u8; 4096];
        match r.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => body.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(Response { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fake stream that yields the scripted chunks one `read` at a
    /// time, then `WouldBlock` forever (an idle keep-alive socket) —
    /// or EOF when `eof_after` is set.  Writes are discarded.
    struct ChunkStream {
        chunks: std::collections::VecDeque<Vec<u8>>,
        eof_after: bool,
    }

    impl ChunkStream {
        fn new(chunks: &[&str], eof_after: bool) -> ChunkStream {
            ChunkStream {
                chunks: chunks.iter().map(|c| c.as_bytes().to_vec()).collect(),
                eof_after,
            }
        }
    }

    impl Read for ChunkStream {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            match self.chunks.pop_front() {
                Some(c) => {
                    assert!(c.len() <= out.len(), "test chunk larger than read buffer");
                    out[..c.len()].copy_from_slice(&c);
                    Ok(c.len())
                }
                None if self.eof_after => Ok(0),
                None => Err(io::Error::new(ErrorKind::WouldBlock, "idle")),
            }
        }
    }

    impl Write for ChunkStream {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    fn recv_one(chunks: &[&str]) -> Result<Recv, HttpError> {
        HttpConn::new(ChunkStream::new(chunks, false)).recv(1024)
    }

    #[test]
    fn parses_request_split_across_reads() {
        let got = recv_one(&[
            "POST /pre",
            "dict?model=tiny HTTP/1.1\r\nContent-Le",
            "ngth: 12\r\nX-Extra: 1\r\n\r\n{\"tok",
            "ens\":1}",
        ])
        .unwrap();
        let Recv::Request(req) = got else { panic!("expected a request, got {got:?}") };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert_eq!(req.query.get("model").map(|s| s.as_str()), Some("tiny"));
        assert_eq!(req.body, b"{\"tokens\":1}".to_vec());
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn idle_then_complete() {
        // first attempt times out mid-head; the carry-over buffer makes
        // the second attempt complete the same request
        let mut conn = HttpConn::new(ChunkStream::new(&["GET /healthz HT"], false));
        assert!(matches!(conn.recv(1024), Ok(Recv::Idle)));
        conn.stream.chunks.push_back(b"TP/1.1\r\n\r\n".to_vec());
        let Ok(Recv::Request(req)) = conn.recv(1024) else { panic!("second recv") };
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.body, Vec::<u8>::new());
    }

    #[test]
    fn pipelined_requests_parse_sequentially() {
        let two = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut conn = HttpConn::new(ChunkStream::new(&[two], false));
        let Ok(Recv::Request(a)) = conn.recv(1024) else { panic!("first") };
        let Ok(Recv::Request(b)) = conn.recv(1024) else { panic!("second") };
        assert_eq!((a.path.as_str(), b.path.as_str()), ("/a", "/b"));
        assert!(a.keep_alive && !b.keep_alive);
    }

    #[test]
    fn clean_eof_between_requests() {
        let mut conn = HttpConn::new(ChunkStream::new(&[], true));
        assert!(matches!(conn.recv(1024), Ok(Recv::Eof)));
        // EOF mid-request is a protocol error, not a clean close
        let mut conn = HttpConn::new(ChunkStream::new(&["GET /x HT"], true));
        let err = conn.recv(1024).unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn bad_method_maps_to_405_and_garbage_to_400() {
        let err = recv_one(&["DELETE /x HTTP/1.1\r\n\r\n"]).unwrap_err();
        assert_eq!(err.status, 405);
        let err = recv_one(&["not a request\r\n\r\n"]).unwrap_err();
        assert_eq!(err.status, 400);
        let err = recv_one(&["GET /x SPDY/9\r\n\r\n"]).unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn oversized_and_invalid_bodies_are_rejected() {
        let err = recv_one(&["POST /p HTTP/1.1\r\nContent-Length: 9999\r\n\r\n"]).unwrap_err();
        assert_eq!(err.status, 413, "body over max_body=1024");
        let err = recv_one(&["POST /p HTTP/1.1\r\nContent-Length: nope\r\n\r\n"]).unwrap_err();
        assert_eq!(err.status, 400);
        let err =
            recv_one(&["POST /p HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"]).unwrap_err();
        assert_eq!(err.status, 501);
    }

    #[test]
    fn response_roundtrip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 200, "application/json", b"{\"ok\":true}", true).unwrap();
        let mut carry = Vec::new();
        let resp = read_response(&mut wire.as_slice(), &mut carry, 1024).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"{\"ok\":true}");
        assert_eq!(resp.headers.get("connection").map(|s| s.as_str()), Some("keep-alive"));
        assert!(carry.is_empty());
    }

    #[test]
    fn pipelined_response_bytes_survive_in_the_carry_buffer() {
        // two responses land in one read: the bytes past the first
        // body must stay in `carry` and parse as the second response
        let mut wire = Vec::new();
        write_response(&mut wire, 200, "application/json", b"first", true).unwrap();
        write_response(&mut wire, 404, "application/json", b"second!", false).unwrap();
        let mut stream = ChunkStream::new(&[std::str::from_utf8(&wire).unwrap()], true);
        let mut carry = Vec::new();
        let a = read_response(&mut stream, &mut carry, 1024).unwrap();
        assert_eq!((a.status, a.body.as_slice()), (200, b"first".as_slice()));
        assert!(!carry.is_empty(), "second response must be carried, not dropped");
        let b = read_response(&mut stream, &mut carry, 1024).unwrap();
        assert_eq!((b.status, b.body.as_slice()), (404, b"second!".as_slice()));
        assert!(carry.is_empty());
    }

    #[test]
    fn response_content_length_is_capped_and_validated() {
        let mut carry = Vec::new();
        let huge = "HTTP/1.1 200 OK\r\nContent-Length: 99999\r\n\r\n";
        let err = read_response(&mut ChunkStream::new(&[huge], true), &mut carry, 1024)
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData, "over-cap length must fail: {err}");
        assert!(err.to_string().contains("cap"), "classified message, got {err}");
        let mut carry = Vec::new();
        let garbage = "HTTP/1.1 200 OK\r\nContent-Length: nope\r\n\r\n";
        let err = read_response(&mut ChunkStream::new(&[garbage], true), &mut carry, 1024)
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData, "garbage length must fail, not read 0");
    }

    #[test]
    fn streaming_reader_consumes_close_delimited_bodies() {
        let wire = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nConnection: close\r\n\r\n{\"token\":1}\n{\"done\":true}\n";
        let mut carry = Vec::new();
        let resp = read_response_streaming(
            &mut ChunkStream::new(&[wire], true),
            &mut carry,
            1024,
        )
        .unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"{\"token\":1}\n{\"done\":true}\n");
        // with a declared length it degrades to the fixed-length read
        let mut wire = Vec::new();
        write_response(&mut wire, 503, "application/json", b"{\"error\":\"busy\"}", false)
            .unwrap();
        let mut carry = Vec::new();
        let resp = read_response_streaming(
            &mut ChunkStream::new(&[std::str::from_utf8(&wire).unwrap()], true),
            &mut carry,
            1024,
        )
        .unwrap();
        assert_eq!((resp.status, resp.body.as_slice()), (503, b"{\"error\":\"busy\"}".as_slice()));
    }

    #[test]
    fn streaming_head_roundtrips_through_the_streaming_reader() {
        let mut wire = Vec::new();
        write_streaming_head(
            &mut wire,
            200,
            "application/x-ndjson",
            &[("X-Stage-Timings", "parse=1;queue=0;batch=0;compute=9;reply=0".to_string())],
        )
        .unwrap();
        wire.extend_from_slice(b"{\"token\":5,\"pos\":3}\n{\"done\":true,\"tokens\":1}\n");
        let text = String::from_utf8(wire).unwrap();
        assert!(!text.to_ascii_lowercase().contains("content-length"));
        let mut carry = Vec::new();
        let resp = read_response_streaming(
            &mut ChunkStream::new(&[text.as_str()], true),
            &mut carry,
            1024,
        )
        .unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.headers.contains_key("x-stage-timings"));
        assert_eq!(resp.body, b"{\"token\":5,\"pos\":3}\n{\"done\":true,\"tokens\":1}\n");
        assert!(carry.is_empty(), "close-delimited stream leaves no pipelined leftovers");
    }

    #[test]
    fn request_writer_parses_back() {
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/predict", b"{}").unwrap();
        let text = std::str::from_utf8(&wire).unwrap();
        let mut conn = HttpConn::new(ChunkStream::new(&[text], false));
        let Ok(Recv::Request(req)) = conn.recv(1024) else { panic!("parse") };
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{}");
    }

    #[test]
    fn slow_request_bytes_match_a_normal_request() {
        let mut fast = Vec::new();
        write_request(&mut fast, "POST", "/predict", b"{\"tokens\":[1,2]}").unwrap();
        let mut slow = Vec::new();
        write_request_slowly(
            &mut slow,
            "POST",
            "/predict",
            b"{\"tokens\":[1,2]}",
            4,
            std::time::Duration::ZERO,
        )
        .unwrap();
        assert_eq!(fast, slow, "slow-loris differs only in timing, never in bytes");
    }

    #[test]
    fn truncated_request_surfaces_as_mid_request_close() {
        // the server-side parser must classify a mid-body disconnect as
        // a 400 protocol error, not hang or panic
        let mut wire = Vec::new();
        write_request_truncated(&mut wire, "POST", "/predict", b"{\"tokens\":[1,2,3]}", 5)
            .unwrap();
        let text = std::str::from_utf8(&wire).unwrap().to_string();
        assert!(text.contains("Content-Length: 18"), "full length declared: {text}");
        let mut conn = HttpConn::new(ChunkStream::new(&[text.as_str()], true));
        let err = conn.recv(1024).unwrap_err();
        assert_eq!(err.status, 400, "mid-request EOF is the 400 path: {err}");
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c"), "a b c");
        assert_eq!(percent_decode("plain"), "plain");
        assert_eq!(percent_decode("bad%zz"), "bad%zz");
    }
}
