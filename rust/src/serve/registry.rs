//! The model registry: named checkpoints/manifests loaded through the
//! shared [`Engine`] cache, looked up per request and hot-reloadable
//! while the server runs.
//!
//! Each entry is an immutable snapshot (`Arc<ModelEntry>`): manifest,
//! the loaded `predict` executable, and the parameter tensors.  In-flight
//! micro-batches hold the `Arc` they were formed with, so a concurrent
//! reload (`POST /models/reload`) never swaps weights under a running
//! forward — requests simply start seeing the new snapshot once it
//! lands.  A reload that fails (corrupt checkpoint, missing manifest)
//! leaves the old snapshot serving and surfaces the error to the caller.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Context, Result};

use crate::model::{checkpoint, ModelState};
use crate::runtime::{Engine, Executable, HostTensor, Manifest, ModelMeta};
use crate::util::json::Json;

/// Where a model's manifest + weights come from (kept for hot reload).
#[derive(Clone, Debug)]
pub enum ModelSource {
    /// Synthetic zero-artifact config: params from the `init` program.
    Synthetic { meta: ModelMeta, seed: u32 },
    /// An artifact directory (`manifest.json`), optionally with a
    /// trained checkpoint for the weights (else `init` from `seed`).
    Dir { dir: PathBuf, ckpt: Option<PathBuf>, seed: u32 },
}

/// One immutable loaded-model snapshot.
pub struct ModelEntry {
    pub name: String,
    pub manifest: Manifest,
    pub exe: Arc<dyn Executable>,
    pub params: Vec<HostTensor>,
    pub source: ModelSource,
    /// Bumped on every (re)load, so clients can observe a reload.
    pub version: u64,
}

impl ModelEntry {
    /// The `(params…, tokens)` input list for one predict call.
    pub fn predict_inputs<'a>(&'a self, tokens: &'a HostTensor) -> Vec<&'a HostTensor> {
        let mut inputs: Vec<&HostTensor> = Vec::with_capacity(self.params.len() + 1);
        inputs.extend(self.params.iter());
        inputs.push(tokens);
        inputs
    }

    /// One row of the `/models` listing.
    pub fn describe(&self) -> Json {
        let m = &self.manifest.meta;
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("task", Json::str(&m.task)),
            ("variant", Json::str(&m.variant)),
            ("seq_len", Json::num(m.seq_len as f64)),
            ("n_classes", Json::num(m.n_classes as f64)),
            ("vocab", Json::num(m.vocab as f64)),
            ("dual", Json::Bool(m.dual)),
            ("version", Json::num(self.version as f64)),
            ("params", Json::num(self.manifest.total_param_elems() as f64)),
        ])
    }
}

/// Named-model table behind the serve endpoints.
pub struct Registry {
    engine: Arc<Engine>,
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl Registry {
    pub fn new(engine: Arc<Engine>) -> Registry {
        Registry { engine, models: RwLock::new(BTreeMap::new()) }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Load `source` and register it under the manifest key (or the
    /// explicit `name` override).  Returns the entry.
    pub fn load(&self, name: Option<String>, source: ModelSource) -> Result<Arc<ModelEntry>> {
        let prior_version = |n: &str| {
            self.models.read().unwrap().get(n).map(|e| e.version).unwrap_or(0)
        };
        let entry = self.build(name, source, &prior_version)?;
        self.models.write().unwrap().insert(entry.name.clone(), entry.clone());
        crate::info!(
            "registry: loaded {:?} v{} ({} params, seq {})",
            entry.name,
            entry.version,
            entry.manifest.total_param_elems(),
            entry.manifest.meta.seq_len
        );
        Ok(entry)
    }

    /// Re-read an already-registered model from its recorded source.
    /// The old snapshot keeps serving until the new one is ready.
    pub fn reload(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let source = self
            .get(name)
            .with_context(|| format!("no model {name:?} to reload"))?
            .source
            .clone();
        self.load(Some(name.to_string()), source)
    }

    fn build(
        &self,
        name: Option<String>,
        source: ModelSource,
        prior_version: &dyn Fn(&str) -> u64,
    ) -> Result<Arc<ModelEntry>> {
        let (manifest, ckpt, seed) = match &source {
            ModelSource::Synthetic { meta, seed } => {
                (Manifest::synthetic(meta.clone()), None, *seed)
            }
            ModelSource::Dir { dir, ckpt, seed } => {
                (Manifest::load(dir)?, ckpt.clone(), *seed)
            }
        };
        let name = name.unwrap_or_else(|| manifest.key.clone());
        let exe = self.engine.load(&manifest, "predict")?;
        let params = match ckpt {
            Some(path) => {
                let (state, names) = checkpoint::load(&path)
                    .with_context(|| format!("loading checkpoint for model {name:?}"))?;
                // the same name-by-name contract the trainer enforces
                if names.len() != manifest.params.len() {
                    bail!(
                        "checkpoint has {} params, manifest {} — wrong model?",
                        names.len(),
                        manifest.params.len()
                    );
                }
                for (got, spec) in names.iter().zip(&manifest.params) {
                    if got != &spec.name {
                        bail!("checkpoint parameter {got:?} does not match manifest {:?}", spec.name);
                    }
                }
                // shapes too: a same-architecture checkpoint of different
                // geometry must fail the load (and leave the old snapshot
                // serving on reload), not 500 every subsequent request
                for (tensor, spec) in state.params.iter().zip(&manifest.params) {
                    if tensor.shape != spec.shape {
                        bail!(
                            "checkpoint parameter {:?} has shape {:?}, manifest expects {:?} — wrong geometry?",
                            spec.name,
                            tensor.shape,
                            spec.shape
                        );
                    }
                }
                state.params
            }
            None => ModelState::init(&self.engine, &manifest, seed)?.params,
        };
        Ok(Arc::new(ModelEntry {
            version: prior_version(&name) + 1,
            name,
            manifest,
            exe,
            params,
            source,
        }))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.models.read().unwrap().get(name).cloned()
    }

    /// Resolve a request's model: an explicit name, or the single loaded
    /// model when only one is registered (the common smoke-test shape).
    pub fn resolve(&self, name: Option<&str>) -> Result<Arc<ModelEntry>> {
        let models = self.models.read().unwrap();
        match name {
            Some(n) => models
                .get(n)
                .cloned()
                .with_context(|| format!("unknown model {n:?} (see /models)")),
            None if models.len() == 1 => Ok(models.values().next().unwrap().clone()),
            None => bail!(
                "{} models loaded — pick one with ?model= or a \"model\" body field (see /models)",
                models.len()
            ),
        }
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `/models` payload.
    pub fn describe(&self) -> Json {
        let models = self.models.read().unwrap();
        Json::obj(vec![(
            "models",
            Json::Arr(models.values().map(|e| e.describe()).collect()),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::spec::tiny_meta;

    fn registry_with_tiny() -> Registry {
        let reg = Registry::new(Engine::cpu().unwrap());
        reg.load(None, ModelSource::Synthetic { meta: tiny_meta("cast_topk"), seed: 0 })
            .unwrap();
        reg
    }

    #[test]
    fn load_resolve_and_describe() {
        let reg = registry_with_tiny();
        assert_eq!(reg.len(), 1);
        let e = reg.resolve(None).unwrap();
        assert_eq!(e.name, "text_cast_topk_n64_b2_c4_k16");
        assert_eq!(e.version, 1);
        assert!(reg.resolve(Some("nope")).is_err());
        let desc = reg.describe();
        let arr = desc.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("seq_len").and_then(Json::as_usize), Some(64));
    }

    #[test]
    fn reload_bumps_version_and_keeps_serving() {
        let reg = registry_with_tiny();
        let name = reg.resolve(None).unwrap().name.clone();
        let old = reg.get(&name).unwrap();
        let new = reg.reload(&name).unwrap();
        assert_eq!(new.version, 2);
        assert_eq!(old.version, 1, "old snapshot is untouched");
        assert_eq!(reg.get(&name).unwrap().version, 2);
        assert!(reg.reload("missing").is_err());
    }

    #[test]
    fn every_registry_variant_loads_and_serves() {
        // the serve path resolves variants through the same registry as
        // train/predict — any variant the registry knows must load here
        let reg = Registry::new(Engine::cpu().unwrap());
        for variant in crate::runtime::native::VARIANTS {
            let e = reg
                .load(None, ModelSource::Synthetic { meta: tiny_meta(variant), seed: 0 })
                .unwrap_or_else(|e| panic!("{variant}: {e:#}"));
            assert_eq!(e.manifest.meta.variant, variant);
        }
        assert_eq!(reg.len(), crate::runtime::native::VARIANTS.len());
    }

    #[test]
    fn multi_model_resolution_requires_a_name() {
        let reg = registry_with_tiny();
        reg.load(None, ModelSource::Synthetic { meta: tiny_meta("vanilla"), seed: 0 }).unwrap();
        assert_eq!(reg.len(), 2);
        assert!(reg.resolve(None).is_err(), "ambiguous without a name");
        assert!(reg.resolve(Some("text_vanilla_n64_b2")).is_ok());
    }

    #[test]
    fn checkpoint_load_failures_surface_as_errors() {
        let reg = Registry::new(Engine::cpu().unwrap());
        let dir = std::env::temp_dir().join("cast_serve_registry_test");
        let _ = std::fs::remove_dir_all(&dir);
        let saved = Manifest::synthetic(tiny_meta("cast_topk")).save(&dir).unwrap();
        let bad = dir.join("bad.ckpt");
        std::fs::write(&bad, b"NOTACKPT").unwrap();
        let err = reg
            .load(None, ModelSource::Dir { dir: saved.clone(), ckpt: Some(bad), seed: 0 })
            .unwrap_err();
        assert!(format!("{err:#}").contains("checkpoint"), "{err:#}");
        assert!(reg.is_empty(), "failed load must not register");
        // and the no-checkpoint path works from the same dir
        reg.load(None, ModelSource::Dir { dir: saved, ckpt: None, seed: 0 }).unwrap();
        assert_eq!(reg.len(), 1);
    }
}
