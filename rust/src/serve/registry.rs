//! The model registry: named checkpoints/manifests loaded through the
//! shared [`Engine`] cache, looked up per request and hot-reloadable
//! while the server runs.
//!
//! Each entry is an immutable snapshot (`Arc<ModelEntry>`): manifest,
//! the loaded `predict` executable, and the parameter tensors.  In-flight
//! micro-batches hold the `Arc` they were formed with, so a concurrent
//! reload (`POST /models/reload`) never swaps weights under a running
//! forward — requests simply start seeing the new snapshot once it
//! lands.  A reload that fails (corrupt checkpoint, missing manifest)
//! leaves the old snapshot serving and surfaces the error to the caller.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::model::{checkpoint, ModelState};
use crate::runtime::{Engine, Executable, HostTensor, Manifest, ModelMeta};
use crate::util::json::Json;

/// Where a model's manifest + weights come from (kept for hot reload).
#[derive(Clone, Debug)]
pub enum ModelSource {
    /// Synthetic zero-artifact config: params from the `init` program.
    Synthetic { meta: ModelMeta, seed: u32 },
    /// An artifact directory (`manifest.json`), optionally with a
    /// trained checkpoint for the weights (else `init` from `seed`).
    Dir { dir: PathBuf, ckpt: Option<PathBuf>, seed: u32 },
}

pub const BREAKER_CLOSED: u8 = 0;
pub const BREAKER_HALF_OPEN: u8 = 1;
pub const BREAKER_OPEN: u8 = 2;

/// Per-model circuit breaker.  Consecutive engine failures open it;
/// while open, `/predict` sheds fast with 503 instead of queueing more
/// work onto a failing model.  After `cooldown` one probe request is
/// admitted (half-open): success closes the breaker, failure re-opens
/// it.  The breaker survives hot reloads — it guards the *model name*,
/// not one snapshot — so a reload doesn't reset failure history.
pub struct Breaker {
    threshold: u32,
    cooldown: Duration,
    consecutive: AtomicU32,
    /// [`BREAKER_CLOSED`] / [`BREAKER_HALF_OPEN`] / [`BREAKER_OPEN`].
    state: AtomicU8,
    opened_at: Mutex<Option<Instant>>,
}

impl Breaker {
    pub fn new(threshold: u32, cooldown: Duration) -> Breaker {
        Breaker {
            threshold: threshold.max(1),
            cooldown,
            consecutive: AtomicU32::new(0),
            state: AtomicU8::new(BREAKER_CLOSED),
            opened_at: Mutex::new(None),
        }
    }

    /// May a request for this model proceed?  In the open state, flips
    /// to half-open once the cooldown has elapsed and admits exactly
    /// that one probe.
    pub fn allow(&self) -> bool {
        match self.state.load(Ordering::Acquire) {
            BREAKER_OPEN => {
                let cooled = self
                    .opened_at
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .map(|t| t.elapsed() >= self.cooldown)
                    .unwrap_or(true);
                cooled
                    && self
                        .state
                        .compare_exchange(
                            BREAKER_OPEN,
                            BREAKER_HALF_OPEN,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
            }
            BREAKER_HALF_OPEN => false, // one probe at a time
            _ => true,
        }
    }

    pub fn record_success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
        self.state.store(BREAKER_CLOSED, Ordering::Release);
    }

    pub fn record_failure(&self) {
        let n = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        let state = self.state.load(Ordering::Acquire);
        if state == BREAKER_HALF_OPEN || (state == BREAKER_CLOSED && n >= self.threshold) {
            *self.opened_at.lock().unwrap_or_else(|p| p.into_inner()) = Some(Instant::now());
            self.state.store(BREAKER_OPEN, Ordering::Release);
        }
    }

    pub fn state_code(&self) -> u8 {
        self.state.load(Ordering::Acquire)
    }
}

/// One immutable loaded-model snapshot.
pub struct ModelEntry {
    pub name: String,
    pub manifest: Manifest,
    pub exe: Arc<dyn Executable>,
    pub params: Vec<HostTensor>,
    pub source: ModelSource,
    /// Bumped on every (re)load, so clients can observe a reload.
    pub version: u64,
    /// Shared across reloads of the same name (see [`Breaker`]).
    pub breaker: Arc<Breaker>,
}

impl ModelEntry {
    /// The `(params…, tokens)` input list for one predict call.
    pub fn predict_inputs<'a>(&'a self, tokens: &'a HostTensor) -> Vec<&'a HostTensor> {
        let mut inputs: Vec<&HostTensor> = Vec::with_capacity(self.params.len() + 1);
        inputs.extend(self.params.iter());
        inputs.push(tokens);
        inputs
    }

    /// One row of the `/models` listing.
    pub fn describe(&self) -> Json {
        let m = &self.manifest.meta;
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("task", Json::str(&m.task)),
            ("variant", Json::str(&m.variant)),
            ("seq_len", Json::num(m.seq_len as f64)),
            ("n_classes", Json::num(m.n_classes as f64)),
            ("vocab", Json::num(m.vocab as f64)),
            ("dual", Json::Bool(m.dual)),
            ("version", Json::num(self.version as f64)),
            ("params", Json::num(self.manifest.total_param_elems() as f64)),
        ])
    }
}

/// Named-model table behind the serve endpoints.
pub struct Registry {
    engine: Arc<Engine>,
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    /// Breaker parameters applied to newly loaded model names
    /// (`cast serve --breaker-failures` / `--breaker-cooldown-ms`).
    breaker_threshold: u32,
    breaker_cooldown: Duration,
}

impl Registry {
    pub fn new(engine: Arc<Engine>) -> Registry {
        Registry::with_breaker(engine, 5, Duration::from_secs(5))
    }

    /// A registry whose models get circuit breakers with the given
    /// consecutive-failure threshold and open-state cooldown.  Existing
    /// entries keep their breakers (reloads carry them over).
    pub fn with_breaker(engine: Arc<Engine>, threshold: u32, cooldown: Duration) -> Registry {
        Registry {
            engine,
            models: RwLock::new(BTreeMap::new()),
            breaker_threshold: threshold,
            breaker_cooldown: cooldown,
        }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Read the model table, recovering from a poisoned lock (a reader
    /// or writer that panicked mid-access left the map itself intact —
    /// entries are immutable `Arc`s and inserts are single operations).
    fn read_models(&self) -> RwLockReadGuard<'_, BTreeMap<String, Arc<ModelEntry>>> {
        self.models.read().unwrap_or_else(|p| p.into_inner())
    }

    fn write_models(&self) -> RwLockWriteGuard<'_, BTreeMap<String, Arc<ModelEntry>>> {
        self.models.write().unwrap_or_else(|p| p.into_inner())
    }

    /// Load `source` and register it under the manifest key (or the
    /// explicit `name` override).  Returns the entry.
    pub fn load(&self, name: Option<String>, source: ModelSource) -> Result<Arc<ModelEntry>> {
        let prior = |n: &str| self.read_models().get(n).cloned();
        let entry = self.build(name, source, &prior)?;
        self.write_models().insert(entry.name.clone(), entry.clone());
        crate::info!(
            "registry: loaded {:?} v{} ({} params, seq {})",
            entry.name,
            entry.version,
            entry.manifest.total_param_elems(),
            entry.manifest.meta.seq_len
        );
        Ok(entry)
    }

    /// Re-read an already-registered model from its recorded source.
    /// The old snapshot keeps serving until the new one is ready.
    pub fn reload(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let source = self
            .get(name)
            .with_context(|| format!("no model {name:?} to reload"))?
            .source
            .clone();
        self.load(Some(name.to_string()), source)
    }

    fn build(
        &self,
        name: Option<String>,
        source: ModelSource,
        prior: &dyn Fn(&str) -> Option<Arc<ModelEntry>>,
    ) -> Result<Arc<ModelEntry>> {
        let (manifest, ckpt, seed) = match &source {
            ModelSource::Synthetic { meta, seed } => {
                (Manifest::synthetic(meta.clone()), None, *seed)
            }
            ModelSource::Dir { dir, ckpt, seed } => {
                (Manifest::load(dir)?, ckpt.clone(), *seed)
            }
        };
        let name = name.unwrap_or_else(|| manifest.key.clone());
        let exe = self.engine.load(&manifest, "predict")?;
        let params = match ckpt {
            Some(path) => {
                let (state, names) = checkpoint::load(&path)
                    .with_context(|| format!("loading checkpoint for model {name:?}"))?;
                // the same name-by-name contract the trainer enforces
                if names.len() != manifest.params.len() {
                    bail!(
                        "checkpoint has {} params, manifest {} — wrong model?",
                        names.len(),
                        manifest.params.len()
                    );
                }
                for (got, spec) in names.iter().zip(&manifest.params) {
                    if got != &spec.name {
                        bail!("checkpoint parameter {got:?} does not match manifest {:?}", spec.name);
                    }
                }
                // shapes too: a same-architecture checkpoint of different
                // geometry must fail the load (and leave the old snapshot
                // serving on reload), not 500 every subsequent request
                for (tensor, spec) in state.params.iter().zip(&manifest.params) {
                    if tensor.shape != spec.shape {
                        bail!(
                            "checkpoint parameter {:?} has shape {:?}, manifest expects {:?} — wrong geometry?",
                            spec.name,
                            tensor.shape,
                            spec.shape
                        );
                    }
                }
                state.params
            }
            None => ModelState::init(&self.engine, &manifest, seed)?.params,
        };
        let prior = prior(&name);
        Ok(Arc::new(ModelEntry {
            version: prior.as_ref().map(|e| e.version).unwrap_or(0) + 1,
            breaker: prior.map(|e| e.breaker.clone()).unwrap_or_else(|| {
                Arc::new(Breaker::new(self.breaker_threshold, self.breaker_cooldown))
            }),
            name,
            manifest,
            exe,
            params,
            source,
        }))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.read_models().get(name).cloned()
    }

    /// Resolve a request's model: an explicit name, or the single loaded
    /// model when only one is registered (the common smoke-test shape).
    pub fn resolve(&self, name: Option<&str>) -> Result<Arc<ModelEntry>> {
        let models = self.read_models();
        match name {
            Some(n) => models
                .get(n)
                .cloned()
                .with_context(|| format!("unknown model {n:?} (see /models)")),
            None if models.len() == 1 => Ok(models.values().next().unwrap().clone()),
            None => bail!(
                "{} models loaded — pick one with ?model= or a \"model\" body field (see /models)",
                models.len()
            ),
        }
    }

    pub fn len(&self) -> usize {
        self.read_models().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Each model's circuit-breaker state, for `/metrics` and `/readyz`.
    pub fn breaker_states(&self) -> Vec<(String, u8)> {
        self.read_models().iter().map(|(n, e)| (n.clone(), e.breaker.state_code())).collect()
    }

    /// The `/models` payload.
    pub fn describe(&self) -> Json {
        let models = self.read_models();
        Json::obj(vec![(
            "models",
            Json::Arr(models.values().map(|e| e.describe()).collect()),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::spec::tiny_meta;

    fn registry_with_tiny() -> Registry {
        let reg = Registry::new(Engine::cpu().unwrap());
        reg.load(None, ModelSource::Synthetic { meta: tiny_meta("cast_topk"), seed: 0 })
            .unwrap();
        reg
    }

    #[test]
    fn load_resolve_and_describe() {
        let reg = registry_with_tiny();
        assert_eq!(reg.len(), 1);
        let e = reg.resolve(None).unwrap();
        assert_eq!(e.name, "text_cast_topk_n64_b2_c4_k16");
        assert_eq!(e.version, 1);
        assert!(reg.resolve(Some("nope")).is_err());
        let desc = reg.describe();
        let arr = desc.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("seq_len").and_then(Json::as_usize), Some(64));
    }

    #[test]
    fn reload_bumps_version_and_keeps_serving() {
        let reg = registry_with_tiny();
        let name = reg.resolve(None).unwrap().name.clone();
        let old = reg.get(&name).unwrap();
        let new = reg.reload(&name).unwrap();
        assert_eq!(new.version, 2);
        assert_eq!(old.version, 1, "old snapshot is untouched");
        assert_eq!(reg.get(&name).unwrap().version, 2);
        assert!(reg.reload("missing").is_err());
        // the breaker guards the name, not one snapshot: failure history
        // (and an open breaker) must survive a hot reload
        assert!(
            Arc::ptr_eq(&old.breaker, &new.breaker),
            "reload must carry the breaker over"
        );
    }

    #[test]
    fn breaker_opens_after_threshold_and_recovers_via_half_open() {
        let b = Breaker::new(3, Duration::from_millis(30));
        assert!(b.allow());
        b.record_failure();
        b.record_failure();
        assert!(b.allow(), "below threshold stays closed");
        b.record_failure();
        assert_eq!(b.state_code(), BREAKER_OPEN);
        assert!(!b.allow(), "open before cooldown sheds");
        std::thread::sleep(Duration::from_millis(40));
        assert!(b.allow(), "cooldown elapsed: one probe admitted");
        assert_eq!(b.state_code(), BREAKER_HALF_OPEN);
        assert!(!b.allow(), "only one probe at a time");
        b.record_success();
        assert_eq!(b.state_code(), BREAKER_CLOSED);
        assert!(b.allow());
    }

    #[test]
    fn breaker_failed_probe_reopens_immediately() {
        let b = Breaker::new(1, Duration::from_millis(20));
        b.record_failure();
        assert_eq!(b.state_code(), BREAKER_OPEN);
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.allow(), "probe admitted");
        b.record_failure();
        assert_eq!(b.state_code(), BREAKER_OPEN, "failed probe re-opens");
        assert!(!b.allow(), "cooldown restarts after a failed probe");
    }

    #[test]
    fn breaker_success_resets_the_consecutive_count() {
        let b = Breaker::new(3, Duration::from_secs(60));
        b.record_failure();
        b.record_failure();
        b.record_success();
        b.record_failure();
        b.record_failure();
        assert_eq!(b.state_code(), BREAKER_CLOSED, "non-consecutive failures never open");
        assert!(b.allow());
    }

    #[test]
    fn with_breaker_applies_cli_threshold_to_new_models() {
        let reg =
            Registry::with_breaker(Engine::cpu().unwrap(), 1, Duration::from_secs(60));
        let e = reg
            .load(None, ModelSource::Synthetic { meta: tiny_meta("cast_topk"), seed: 0 })
            .unwrap();
        e.breaker.record_failure();
        assert_eq!(e.breaker.state_code(), BREAKER_OPEN, "threshold 1 opens on one failure");
        // a reload keeps the (open) breaker rather than minting a new one
        let again = reg.reload(&e.name).unwrap();
        assert!(Arc::ptr_eq(&e.breaker, &again.breaker));
    }

    #[test]
    fn every_registry_variant_loads_and_serves() {
        // the serve path resolves variants through the same registry as
        // train/predict — any variant the registry knows must load here
        let reg = Registry::new(Engine::cpu().unwrap());
        for variant in crate::runtime::native::VARIANTS {
            let e = reg
                .load(None, ModelSource::Synthetic { meta: tiny_meta(variant), seed: 0 })
                .unwrap_or_else(|e| panic!("{variant}: {e:#}"));
            assert_eq!(e.manifest.meta.variant, variant);
        }
        assert_eq!(reg.len(), crate::runtime::native::VARIANTS.len());
    }

    #[test]
    fn multi_model_resolution_requires_a_name() {
        let reg = registry_with_tiny();
        reg.load(None, ModelSource::Synthetic { meta: tiny_meta("vanilla"), seed: 0 }).unwrap();
        assert_eq!(reg.len(), 2);
        assert!(reg.resolve(None).is_err(), "ambiguous without a name");
        assert!(reg.resolve(Some("text_vanilla_n64_b2")).is_ok());
    }

    #[test]
    fn checkpoint_load_failures_surface_as_errors() {
        let reg = Registry::new(Engine::cpu().unwrap());
        let dir = std::env::temp_dir().join("cast_serve_registry_test");
        let _ = std::fs::remove_dir_all(&dir);
        let saved = Manifest::synthetic(tiny_meta("cast_topk")).save(&dir).unwrap();
        let bad = dir.join("bad.ckpt");
        std::fs::write(&bad, b"NOTACKPT").unwrap();
        let err = reg
            .load(None, ModelSource::Dir { dir: saved.clone(), ckpt: Some(bad), seed: 0 })
            .unwrap_err();
        assert!(format!("{err:#}").contains("checkpoint"), "{err:#}");
        assert!(reg.is_empty(), "failed load must not register");
        // and the no-checkpoint path works from the same dir
        reg.load(None, ModelSource::Dir { dir: saved, ckpt: None, seed: 0 }).unwrap();
        assert_eq!(reg.len(), 1);
    }
}
