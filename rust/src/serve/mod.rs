//! The serving subsystem: `cast serve` — a dependency-free HTTP/1.1
//! inference server with dynamic micro-batching — and `cast loadgen`,
//! its closed-loop measurement client.
//!
//! Layers (each its own module, DESIGN.md §Serving):
//!
//! * [`http`] — minimal HTTP/1.1 parser/writer (split-read safe,
//!   keep-alive, fixed-length bodies) over `std::net`.
//! * [`registry`] — named model snapshots loaded through the shared
//!   [`Engine`](crate::runtime::Engine) cache; `/models`, hot reload.
//! * [`batcher`] — the dynamic micro-batcher: a bounded job queue
//!   coalesces concurrent `/predict` requests into padded single-model
//!   batches (≤ `max_batch` rows, ≤ `max_wait`), runs them through one
//!   engine forward with per-worker reusable scratch, and demultiplexes
//!   the logits back to each connection.
//! * [`metrics`] — atomic counters/histograms rendered on `/metrics`.
//! * [`server`] — acceptor + connection worker pool, routing, graceful
//!   drain on SIGTERM/SIGINT or `/admin/shutdown`; worker panics are
//!   caught and contained, deadline-expired jobs are shed with 503, and
//!   a per-model circuit breaker fails fast (DESIGN.md §Robustness).
//! * [`loadgen`] — the `--conns`/`--requests` closed-loop client that
//!   appends `serve_reqs_per_sec` rows to `BENCH_native.json`.
//!
//! Determinism contract: batching never changes results.  The native
//! forward treats batch rows independently and is bit-identical across
//! thread counts, so the logits for a sequence are the same whether it
//! rode in a batch of 1 or 8 — `tests/integration_serve.rs` asserts
//! byte-equal JSON against sequential single-row predicts.

pub mod batcher;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod registry;
pub mod server;

pub use loadgen::{LoadReport, LoadgenConfig};
pub use metrics::Metrics;
pub use registry::{Breaker, ModelEntry, ModelSource, Registry};
pub use server::{install_signal_handlers, ServeConfig, Server};
