//! Serve observability: lock-free counters and histograms rendered as
//! Prometheus-style text on `/metrics`.
//!
//! Everything is atomics — the hot path (connection workers timing
//! requests, inference workers recording batch sizes) never takes a
//! lock.  Quantiles (p50/p99) are interpolated from the latency
//! histogram's cumulative counts, which is exactly how a Prometheus
//! server would evaluate `histogram_quantile()` over these buckets; the
//! loadgen client reports exact client-side percentiles alongside.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::runtime::native::cluster_stats::Summary as ClusterSummary;

/// Latency buckets in seconds (log-ish spacing, +Inf implied).
const LATENCY_BOUNDS: [f64; 14] = [
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
];

/// Micro-batch size buckets in rows (+Inf implied).
const BATCH_BOUNDS: [f64; 8] = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];

/// One Prometheus histogram: `bounds.len() + 1` cumulative-on-render
/// buckets, a sum, and a count.
pub struct Histogram {
    bounds: &'static [f64],
    counts: Vec<AtomicU64>,
    /// Sum in micro-units (µs for seconds-valued histograms, micro-rows
    /// for the batch histogram) so it stays an integer atomic.
    sum_micro: AtomicU64,
}

impl Histogram {
    fn new(bounds: &'static [f64]) -> Histogram {
        Histogram {
            bounds,
            counts: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            sum_micro: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: f64) {
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_micro.fetch_add((v.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> f64 {
        self.sum_micro.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Interpolated quantile (0 < q < 1) from the bucket counts, the
    /// `histogram_quantile()` estimate.  0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = q * total as f64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n == 0 {
                cum += n;
                continue;
            }
            if cum as f64 + n as f64 >= rank {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    // +Inf bucket: report its lower bound
                    return lo;
                };
                let into = (rank - cum as f64) / n as f64;
                return lo + (hi - lo) * into.clamp(0.0, 1.0);
            }
            cum += n;
        }
        *self.bounds.last().unwrap_or(&0.0)
    }

    fn render(&self, name: &str, help: &str, out: &mut String) {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
        self.render_series(name, "", out);
    }

    /// Emit the `_bucket`/`_sum`/`_count` series.  `label` is an extra
    /// label pair spliced before `le` (e.g. `stage="queue",`) so one
    /// metric family can carry several labeled histograms; empty for
    /// the unlabeled case.
    fn render_series(&self, name: &str, label: &str, out: &mut String) {
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            let le = if i < self.bounds.len() {
                trim_float(self.bounds[i])
            } else {
                "+Inf".to_string()
            };
            out.push_str(&format!("{name}_bucket{{{label}le=\"{le}\"}} {cum}\n"));
        }
        let sfx = match label.strip_suffix(',') {
            Some(l) => format!("{{{l}}}"),
            None => String::new(),
        };
        out.push_str(&format!("{name}_sum{sfx} {}\n", trim_float(self.sum())));
        out.push_str(&format!("{name}_count{sfx} {cum}\n"));
    }
}

/// Shortest plain rendering of a bucket bound ("0.005", "1", "2.5").
fn trim_float(v: f64) -> String {
    if v.fract() == 0.0 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// The request-path endpoints we count separately.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    Predict,
    Generate,
    Models,
    Metrics,
    Healthz,
    Reload,
    Shutdown,
    DebugTrace,
    DebugClusters,
    Other,
}

const ENDPOINTS: [(Endpoint, &str); 10] = [
    (Endpoint::Predict, "predict"),
    (Endpoint::Generate, "generate"),
    (Endpoint::Models, "models"),
    (Endpoint::Metrics, "metrics"),
    (Endpoint::Healthz, "healthz"),
    (Endpoint::Reload, "reload"),
    (Endpoint::Shutdown, "shutdown"),
    (Endpoint::DebugTrace, "debug_trace"),
    (Endpoint::DebugClusters, "debug_clusters"),
    (Endpoint::Other, "other"),
];

fn endpoint_index(e: Endpoint) -> usize {
    ENDPOINTS.iter().position(|(k, _)| *k == e).unwrap()
}

/// Labels of the /predict pipeline stages, in pipeline order.  Indexes
/// line up with [`Metrics::stages`] and [`Metrics::observe_stages`].
pub const STAGES: [&str; 5] = ["parse", "queue", "batch", "compute", "reply"];

/// All serve metrics, shared across every worker via `Arc`.
pub struct Metrics {
    started: Instant,
    requests: [AtomicU64; 10],
    responses_2xx: AtomicU64,
    responses_4xx: AtomicU64,
    responses_5xx: AtomicU64,
    predict_rows: AtomicU64,
    batches: AtomicU64,
    /// Requests refused before compute (breaker open, queue full, or
    /// deadline exhausted while queued) — every shed is a 503.
    shed: AtomicU64,
    /// Jobs whose `X-Deadline-Ms` budget ran out waiting in the queue.
    deadline_exceeded: AtomicU64,
    /// Panics caught and contained in serve workers (infer or conn).
    worker_panics: AtomicU64,
    /// Tokens streamed out of `/generate` responses.
    generate_tokens: AtomicU64,
    /// Decode tokens absorbed after every cluster slot filled — the
    /// Nc·κ zero-attention passthrough dead-end made visible.
    decode_passthrough: AtomicU64,
    /// Last observed decode cluster-cache fill (occupied slots / total
    /// slots across layers), updated as `/generate` sessions finish.
    decode_cache_fill: AtomicU64,
    decode_cache_capacity: AtomicU64,
    /// Per-model cluster-health gauges, harvested from
    /// `cluster_stats::take_summary()` after batches/streams complete.
    /// The one non-atomic member: updated per *batch*, not per request,
    /// so a Mutex off the hot path is fine.
    cluster_health: Mutex<Vec<(String, ClusterSummary)>>,
    pub batch_rows: Histogram,
    pub latency: Histogram,
    /// Per-/predict pipeline stage wall time, indexed as [`STAGES`].
    pub stages: [Histogram; 5],
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests: std::array::from_fn(|_| AtomicU64::new(0)),
            responses_2xx: AtomicU64::new(0),
            responses_4xx: AtomicU64::new(0),
            responses_5xx: AtomicU64::new(0),
            predict_rows: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_exceeded: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            generate_tokens: AtomicU64::new(0),
            decode_passthrough: AtomicU64::new(0),
            decode_cache_fill: AtomicU64::new(0),
            decode_cache_capacity: AtomicU64::new(0),
            cluster_health: Mutex::new(Vec::new()),
            batch_rows: Histogram::new(&BATCH_BOUNDS),
            latency: Histogram::new(&LATENCY_BOUNDS),
            stages: std::array::from_fn(|_| Histogram::new(&LATENCY_BOUNDS)),
        }
    }

    /// Record one /predict request's pipeline split (seconds per stage,
    /// in [`STAGES`] order: parse, queue wait, batch formation, compute,
    /// reply serialization).
    pub fn observe_stages(&self, seconds: [f64; 5]) {
        for (h, v) in self.stages.iter().zip(seconds) {
            h.observe(v);
        }
    }

    pub fn inc_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` tokens streamed from one `/generate` response.
    pub fn observe_generate_tokens(&self, n: usize) {
        self.generate_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn generate_tokens_total(&self) -> u64 {
        self.generate_tokens.load(Ordering::Relaxed)
    }

    /// Record one finished decode session's cluster-cache health:
    /// passthrough tokens it produced and its final cache fill level.
    pub fn observe_decode_session(&self, passthrough: u64, fill: usize, capacity: usize) {
        self.decode_passthrough.fetch_add(passthrough, Ordering::Relaxed);
        self.decode_cache_fill.store(fill as u64, Ordering::Relaxed);
        self.decode_cache_capacity.store(capacity as u64, Ordering::Relaxed);
    }

    pub fn decode_passthrough_total(&self) -> u64 {
        self.decode_passthrough.load(Ordering::Relaxed)
    }

    /// Replace `model`'s cluster-health gauges with a fresh harvest.
    pub fn update_cluster_health(&self, model: &str, summary: ClusterSummary) {
        let mut table = self.cluster_health.lock().unwrap_or_else(|p| p.into_inner());
        match table.iter_mut().find(|(name, _)| name == model) {
            Some((_, s)) => *s = summary,
            None => table.push((model.to_string(), summary)),
        }
    }

    /// Current per-model cluster-health gauges (for `/debug/clusters`).
    pub fn cluster_health_snapshot(&self) -> Vec<(String, ClusterSummary)> {
        self.cluster_health.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn deadline_exceeded_total(&self) -> u64 {
        self.deadline_exceeded.load(Ordering::Relaxed)
    }

    pub fn worker_panics_total(&self) -> u64 {
        self.worker_panics.load(Ordering::Relaxed)
    }

    /// Record one handled request: endpoint, response status, wall time.
    pub fn observe_request(&self, endpoint: Endpoint, status: u16, seconds: f64) {
        self.requests[endpoint_index(endpoint)].fetch_add(1, Ordering::Relaxed);
        let class = match status {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        class.fetch_add(1, Ordering::Relaxed);
        if endpoint == Endpoint::Predict {
            self.latency.observe(seconds);
        }
    }

    /// Record one executed micro-batch of `rows` sequences.
    pub fn observe_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.predict_rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.batch_rows.observe(rows as f64);
    }

    pub fn predict_requests(&self) -> u64 {
        self.requests[endpoint_index(Endpoint::Predict)].load(Ordering::Relaxed)
    }

    pub fn error_responses(&self) -> u64 {
        self.responses_4xx.load(Ordering::Relaxed) + self.responses_5xx.load(Ordering::Relaxed)
    }

    /// Render the whole exposition-format page.  `queue_depth` and
    /// `models` are point-in-time gauges supplied by the server;
    /// `breakers` is each model's circuit-breaker state
    /// (0 = closed, 1 = half-open, 2 = open).
    pub fn render(&self, queue_depth: usize, models: usize, breakers: &[(String, u8)]) -> String {
        let mut out = String::with_capacity(2048);
        out.push_str("# HELP cast_serve_requests_total Requests handled, by endpoint.\n");
        out.push_str("# TYPE cast_serve_requests_total counter\n");
        for (e, name) in ENDPOINTS {
            out.push_str(&format!(
                "cast_serve_requests_total{{endpoint=\"{name}\"}} {}\n",
                self.requests[endpoint_index(e)].load(Ordering::Relaxed)
            ));
        }
        out.push_str("# HELP cast_serve_responses_total Responses sent, by status class.\n");
        out.push_str("# TYPE cast_serve_responses_total counter\n");
        for (class, v) in [
            ("2xx", &self.responses_2xx),
            ("4xx", &self.responses_4xx),
            ("5xx", &self.responses_5xx),
        ] {
            out.push_str(&format!(
                "cast_serve_responses_total{{class=\"{class}\"}} {}\n",
                v.load(Ordering::Relaxed)
            ));
        }
        for (name, help, v) in [
            (
                "cast_serve_predict_rows_total",
                "Sequences predicted (batch rows).",
                self.predict_rows.load(Ordering::Relaxed),
            ),
            (
                "cast_serve_batches_total",
                "Micro-batches executed.",
                self.batches.load(Ordering::Relaxed),
            ),
            (
                "cast_serve_shed_total",
                "Requests refused before compute (breaker open or deadline shed).",
                self.shed.load(Ordering::Relaxed),
            ),
            (
                "cast_serve_deadline_exceeded_total",
                "Jobs whose deadline budget expired while queued.",
                self.deadline_exceeded.load(Ordering::Relaxed),
            ),
            (
                "cast_serve_worker_panics_total",
                "Panics caught and contained in serve workers.",
                self.worker_panics.load(Ordering::Relaxed),
            ),
            (
                "cast_serve_generate_tokens_total",
                "Tokens streamed from /generate responses.",
                self.generate_tokens.load(Ordering::Relaxed),
            ),
            (
                "cast_decode_passthrough_tokens_total",
                "Decode tokens absorbed with every cluster-cache slot full \
                 (zero-attention passthrough).",
                self.decode_passthrough.load(Ordering::Relaxed),
            ),
        ] {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
        }
        out.push_str(
            "# HELP cast_serve_breaker_state Circuit breaker per model \
             (0=closed, 1=half-open, 2=open).\n# TYPE cast_serve_breaker_state gauge\n",
        );
        for (model, state) in breakers {
            out.push_str(&format!("cast_serve_breaker_state{{model=\"{model}\"}} {state}\n"));
        }
        for (name, help, v) in [
            (
                "cast_decode_cache_fill_slots",
                "Occupied decode cluster-cache slots when the last /generate \
                 session finished.",
                self.decode_cache_fill.load(Ordering::Relaxed),
            ),
            (
                "cast_decode_cache_capacity_slots",
                "Total decode cluster-cache slots (depth * Nc * kappa) of that \
                 session.",
                self.decode_cache_capacity.load(Ordering::Relaxed),
            ),
        ] {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
        }
        let health = self.cluster_health_snapshot();
        let cluster_families: [(&str, &str, fn(&ClusterSummary) -> f64); 5] = [
            (
                "cast_cluster_affinity_entropy",
                "Mean normalized affinity entropy across layers (1 = uniform, \
                 0 = one-hot).",
                |s| s.entropy,
            ),
            (
                "cast_cluster_balance_cv",
                "Mean coefficient of variation of cluster sizes (0 = perfectly \
                 balanced).",
                |s| s.balance_cv,
            ),
            (
                "cast_cluster_assignment_churn",
                "Mean fraction of tokens whose cluster assignment changed \
                 between forwards.",
                |s| s.churn,
            ),
            (
                "cast_cluster_max_fraction",
                "Largest fraction of tokens captured by any single cluster.",
                |s| s.max_fraction,
            ),
            (
                "cast_cluster_collapsed_layers",
                "Layers latched as collapsed (dominant cluster or degenerate \
                 entropy).",
                |s| s.collapsed_layers as f64,
            ),
        ];
        for (name, help, pick) in cluster_families {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
            for (model, s) in &health {
                out.push_str(&format!("{name}{{model=\"{model}\"}} {}\n", pick(s)));
            }
        }
        self.batch_rows.render(
            "cast_serve_batch_rows",
            "Rows per executed micro-batch.",
            &mut out,
        );
        self.latency.render(
            "cast_serve_request_latency_seconds",
            "Wall time of /predict requests (enqueue to reply).",
            &mut out,
        );
        out.push_str(
            "# HELP cast_serve_stage_seconds Per-request pipeline stage wall time \
             (parse, queue wait, batch formation, compute, reply).\n\
             # TYPE cast_serve_stage_seconds histogram\n",
        );
        for (h, stage) in self.stages.iter().zip(STAGES) {
            h.render_series("cast_serve_stage_seconds", &format!("stage=\"{stage}\","), &mut out);
        }
        for (name, q) in [
            ("cast_serve_request_latency_p50_seconds", 0.5),
            ("cast_serve_request_latency_p99_seconds", 0.99),
        ] {
            out.push_str(&format!(
                "# HELP {name} Interpolated latency quantile.\n# TYPE {name} gauge\n{name} {}\n",
                self.latency.quantile(q)
            ));
        }
        for (name, help, v) in [
            ("cast_serve_queue_depth", "Jobs waiting in the batch queue.", queue_depth as f64),
            ("cast_serve_models", "Models loaded in the registry.", models as f64),
            (
                "cast_serve_uptime_seconds",
                "Seconds since the server started.",
                self.started.elapsed().as_secs_f64(),
            ),
        ] {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
        }
        out
    }
}

/// Promtool-style lint of an exposition page.  Checks, per line:
///
/// * every sample series is preceded by `# HELP` and `# TYPE` lines for
///   its family (histogram `_bucket`/`_sum`/`_count` series resolve to
///   their base name when that base is a declared family);
/// * metric names match `[a-zA-Z_:][a-zA-Z0-9_:]*` and label names
///   match `[a-zA-Z_][a-zA-Z0-9_]*` with double-quoted values;
/// * `# TYPE` kinds are ones Prometheus knows;
/// * every sample carries exactly one parsable numeric value.
///
/// Returns the first violation with its line number, like
/// `promtool check metrics` would.
pub fn lint_exposition(page: &str) -> Result<(), String> {
    use std::collections::HashSet;
    fn valid_name(s: &str) -> bool {
        let mut chars = s.chars();
        match chars.next() {
            Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
            _ => return false,
        }
        chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    fn valid_label(s: &str) -> bool {
        let mut chars = s.chars();
        match chars.next() {
            Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
            _ => return false,
        }
        chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
    }
    let mut helped: HashSet<&str> = HashSet::new();
    let mut typed: HashSet<&str> = HashSet::new();
    for (i, line) in page.lines().enumerate() {
        let ln = i + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("");
            if !valid_name(name) {
                return Err(format!("line {ln}: bad metric name in HELP: {line:?}"));
            }
            helped.insert(name);
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().unwrap_or("");
            let kind = parts.next().unwrap_or("");
            if !valid_name(name) {
                return Err(format!("line {ln}: bad metric name in TYPE: {line:?}"));
            }
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {ln}: unknown TYPE kind {kind:?}"));
            }
            typed.insert(name);
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }
        let name_end = line.find(|c: char| c == '{' || c == ' ').unwrap_or(line.len());
        let name = &line[..name_end];
        if !valid_name(name) {
            return Err(format!("line {ln}: bad series name {name:?}"));
        }
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|sfx| name.strip_suffix(sfx))
            .filter(|base| typed.contains(base))
            .unwrap_or(name);
        if !helped.contains(family) {
            return Err(format!("line {ln}: series {name:?} has no # HELP for {family:?}"));
        }
        if !typed.contains(family) {
            return Err(format!("line {ln}: series {name:?} has no # TYPE for {family:?}"));
        }
        let rest = &line[name_end..];
        let value_part = if let Some(r) = rest.strip_prefix('{') {
            let close = r
                .find('}')
                .ok_or_else(|| format!("line {ln}: unclosed label set: {line:?}"))?;
            for pair in r[..close].split(',').filter(|p| !p.is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| format!("line {ln}: label without '=': {pair:?}"))?;
                if !valid_label(k) {
                    return Err(format!("line {ln}: bad label name {k:?}"));
                }
                if v.len() < 2 || !v.starts_with('"') || !v.ends_with('"') {
                    return Err(format!("line {ln}: label value not quoted: {pair:?}"));
                }
            }
            &r[close + 1..]
        } else {
            rest
        };
        let value = value_part.trim();
        if value.is_empty() || value.split_whitespace().count() != 1 {
            return Err(format!("line {ln}: expected exactly one value: {line:?}"));
        }
        if value.parse::<f64>().is_err() {
            return Err(format!("line {ln}: unparsable sample value {value:?}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_quantiles() {
        let h = Histogram::new(&LATENCY_BOUNDS);
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram");
        for _ in 0..100 {
            h.observe(0.002); // (0.001, 0.0025] bucket
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.001 && p50 <= 0.0025, "p50 {p50} inside the hot bucket");
        // one straggler in a much slower bucket moves p99, not p50
        for _ in 0..5 {
            h.observe(4.9);
        }
        let p99 = h.quantile(0.99);
        assert!(p99 > 2.5, "p99 {p99} pulled up by stragglers");
        assert!(h.quantile(0.5) <= 0.0025);
        assert!((h.sum() - (100.0 * 0.002 + 5.0 * 4.9)).abs() < 0.01);
    }

    #[test]
    fn overflow_bucket_reports_lower_bound() {
        let h = Histogram::new(&BATCH_BOUNDS);
        h.observe(1e6);
        assert_eq!(h.quantile(0.5), 128.0);
    }

    #[test]
    fn render_contains_required_families() {
        let m = Metrics::new();
        m.observe_request(Endpoint::Predict, 200, 0.004);
        m.observe_request(Endpoint::Healthz, 200, 0.0);
        m.observe_request(Endpoint::Predict, 500, 0.1);
        m.observe_request(Endpoint::Generate, 200, 0.2);
        m.observe_generate_tokens(17);
        m.observe_batch(4);
        let page = m.render(3, 2, &[]);
        for needle in [
            "cast_serve_requests_total{endpoint=\"predict\"} 2",
            "cast_serve_requests_total{endpoint=\"generate\"} 1",
            "cast_serve_generate_tokens_total 17",
            "cast_serve_responses_total{class=\"2xx\"} 3",
            "cast_serve_responses_total{class=\"5xx\"} 1",
            "cast_serve_batch_rows_bucket{le=\"4\"} 1",
            "cast_serve_batch_rows_count 1",
            "cast_serve_predict_rows_total 4",
            "cast_serve_request_latency_seconds_count 2",
            "cast_serve_request_latency_p99_seconds",
            "cast_serve_queue_depth 3",
            "cast_serve_models 2",
            "cast_decode_passthrough_tokens_total 0",
            "cast_decode_cache_fill_slots 0",
        ] {
            assert!(page.contains(needle), "missing {needle:?} in:\n{page}");
        }
        assert_eq!(m.predict_requests(), 2);
        assert_eq!(m.error_responses(), 1);
    }

    #[test]
    fn stage_histograms_render_per_label_and_count_requests() {
        let m = Metrics::new();
        m.observe_stages([0.0001, 0.002, 0.0008, 0.02, 0.0001]);
        m.observe_stages([0.0002, 0.004, 0.0010, 0.04, 0.0002]);
        let page = m.render(0, 1, &[]);
        for stage in STAGES {
            let needle = format!("cast_serve_stage_seconds_count{{stage=\"{stage}\"}} 2");
            assert!(page.contains(&needle), "missing {needle:?} in:\n{page}");
        }
        assert!(page.contains("cast_serve_stage_seconds_bucket{stage=\"queue\",le=\"0.0025\"}"));
        // every stage histogram saw exactly one observation per request
        for h in &m.stages {
            assert_eq!(h.count(), 2);
        }
        assert!(m.stages[3].sum() > m.stages[0].sum(), "compute dominates parse");
    }

    #[test]
    fn resilience_counters_export_and_increment() {
        let m = Metrics::new();
        let page = m.render(0, 1, &[("tiny".to_string(), 0)]);
        for needle in [
            "cast_serve_shed_total 0",
            "cast_serve_deadline_exceeded_total 0",
            "cast_serve_worker_panics_total 0",
            "cast_serve_breaker_state{model=\"tiny\"} 0",
        ] {
            assert!(page.contains(needle), "missing {needle:?} in:\n{page}");
        }
        m.inc_shed();
        m.inc_shed();
        m.inc_deadline_exceeded();
        m.inc_worker_panic();
        assert_eq!(m.shed_total(), 2);
        assert_eq!(m.deadline_exceeded_total(), 1);
        assert_eq!(m.worker_panics_total(), 1);
        let page = m.render(0, 1, &[("tiny".to_string(), 2)]);
        for needle in [
            "cast_serve_shed_total 2",
            "cast_serve_deadline_exceeded_total 1",
            "cast_serve_worker_panics_total 1",
            "cast_serve_breaker_state{model=\"tiny\"} 2",
        ] {
            assert!(page.contains(needle), "missing {needle:?} in:\n{page}");
        }
    }

    #[test]
    fn cluster_and_decode_gauges_render_per_model() {
        let m = Metrics::new();
        m.observe_decode_session(3, 5, 24);
        m.observe_decode_session(2, 7, 24);
        assert_eq!(m.decode_passthrough_total(), 5);
        m.update_cluster_health(
            "tiny",
            ClusterSummary {
                layers: 2,
                entropy: 0.875,
                balance_cv: 0.25,
                churn: 0.125,
                max_fraction: 0.5,
                collapsed_layers: 1,
            },
        );
        let page = m.render(0, 1, &[]);
        for needle in [
            "cast_decode_passthrough_tokens_total 5",
            "cast_decode_cache_fill_slots 7",
            "cast_decode_cache_capacity_slots 24",
            "cast_cluster_affinity_entropy{model=\"tiny\"} 0.875",
            "cast_cluster_balance_cv{model=\"tiny\"} 0.25",
            "cast_cluster_assignment_churn{model=\"tiny\"} 0.125",
            "cast_cluster_max_fraction{model=\"tiny\"} 0.5",
            "cast_cluster_collapsed_layers{model=\"tiny\"} 1",
        ] {
            assert!(page.contains(needle), "missing {needle:?} in:\n{page}");
        }
        // a second harvest replaces the model's row rather than stacking
        m.update_cluster_health(
            "tiny",
            ClusterSummary {
                layers: 2,
                entropy: 0.5,
                balance_cv: 0.25,
                churn: 0.125,
                max_fraction: 0.5,
                collapsed_layers: 1,
            },
        );
        let page = m.render(0, 1, &[]);
        assert!(page.contains("cast_cluster_affinity_entropy{model=\"tiny\"} 0.5"));
        assert!(!page.contains("cast_cluster_affinity_entropy{model=\"tiny\"} 0.875"));
        assert_eq!(m.cluster_health_snapshot().len(), 1);
    }

    #[test]
    fn exposition_passes_promtool_style_lint() {
        let m = Metrics::new();
        m.observe_request(Endpoint::Predict, 200, 0.004);
        m.observe_request(Endpoint::DebugClusters, 200, 0.0);
        m.observe_batch(2);
        m.observe_stages([0.0001, 0.002, 0.0008, 0.02, 0.0001]);
        m.observe_decode_session(3, 5, 24);
        m.update_cluster_health(
            "tiny",
            ClusterSummary {
                layers: 2,
                entropy: 0.9,
                balance_cv: 0.1,
                churn: 0.05,
                max_fraction: 0.3,
                collapsed_layers: 0,
            },
        );
        let page = m.render(1, 1, &[("tiny".to_string(), 0)]);
        if let Err(e) = lint_exposition(&page) {
            panic!("lint failed: {e}\n{page}");
        }
    }

    #[test]
    fn lint_rejects_malformed_pages() {
        // series with no HELP/TYPE declaration
        assert!(lint_exposition("loose_series 1\n").is_err());
        // TYPE kind Prometheus doesn't know
        assert!(lint_exposition("# HELP x y\n# TYPE x turbine\nx 1\n").is_err());
        // label name starting with a digit
        assert!(lint_exposition("# HELP x y\n# TYPE x counter\nx{9bad=\"v\"} 1\n").is_err());
        // unquoted label value
        assert!(lint_exposition("# HELP x y\n# TYPE x gauge\nx{a=unquoted} 1\n").is_err());
        // non-numeric sample value
        assert!(lint_exposition("# HELP x y\n# TYPE x counter\nx notanumber\n").is_err());
        // a well-formed page passes
        assert!(lint_exposition("# HELP x y\n# TYPE x counter\nx{a=\"b\"} 1\n").is_ok());
    }
}
