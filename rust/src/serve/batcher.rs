//! The dynamic micro-batcher: concurrent `/predict` requests are
//! coalesced into padded batches and run through the shared engine in
//! one forward, then the logits are demultiplexed back to each waiting
//! connection.
//!
//! Shape: connection workers `push` [`PredictJob`]s into one bounded
//! [`Queue`] (backpressure: pushes block when the queue is full);
//! inference workers pull with a [`BatchFormer`] that waits at most
//! `max_wait` for the batch to fill to `max_batch` rows.  Batches are
//! bucketed by model *snapshot* (the exact `Arc<ModelEntry>`, so a hot
//! reload never mixes weights inside one batch) — and since a model
//! pins one sequence length, buckets are uniform in geometry, keeping
//! CAST's per-cluster shapes identical across the batch.
//!
//! Determinism: the native forward treats batch rows independently and
//! is bit-identical for any thread count (DESIGN.md §Threading), so a
//! row's logits do not depend on which micro-batch it rode in — batched
//! serving returns exactly what sequential `cast eval` would
//! (`tests/integration_serve.rs` pins this down).

use std::collections::VecDeque;
use std::sync::mpsc::SyncSender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::runtime::{HostTensor, Scratch};
use crate::util::parallel::{Pop, Queue};

use super::metrics::Metrics;
use super::registry::ModelEntry;

/// One client request waiting for inference.
pub struct PredictJob {
    /// The model snapshot the request resolved to.
    pub entry: Arc<ModelEntry>,
    /// Padded `(rows, seq_len)` token tensor (`data::batcher::pad_rows`).
    pub tokens: HostTensor,
    /// Sequences in this request.
    pub rows: usize,
    /// Where the connection worker is blocked waiting.
    pub reply: SyncSender<Reply>,
    /// The request's deadline budget (`X-Deadline-Ms` capped by
    /// `--deadline-ms`); a job still queued past this is shed with 503
    /// instead of computed.
    pub deadline: Option<Instant>,
    /// When the connection worker pushed the job (stage timing: the
    /// queue-wait stage runs from here to `popped`).
    pub enqueued: Instant,
    /// When a batch former first pulled the job off the queue (stage
    /// timing: batch formation runs from here to batch execution).
    /// `None` until then; timing fields degrade to zero if unset.
    pub popped: Option<Instant>,
}

/// What each job gets back.
pub type Reply = Result<ReplyOk, ReplyErr>;

/// Why a job failed — the variant carries the HTTP class the server
/// maps it to.
#[derive(Clone, Debug)]
pub enum ReplyErr {
    /// The engine failed (or panicked) executing the batch — 500.
    Engine(String),
    /// Refused before compute (deadline exhausted while queued) — 503
    /// with `Retry-After`.
    Shed(String),
}

impl ReplyErr {
    pub fn message(&self) -> &str {
        match self {
            ReplyErr::Engine(m) | ReplyErr::Shed(m) => m,
        }
    }
}

#[derive(Debug)]
pub struct ReplyOk {
    /// This job's logits, row-major `(rows, n_classes)`.
    pub logits: Vec<f32>,
    pub n_classes: usize,
    /// Total rows in the micro-batch the job rode in (observability).
    pub batch_rows: usize,
    pub model: String,
    pub version: u64,
    /// Stage split, µs: time waiting in the queue, …
    pub queue_us: u64,
    /// … time between the former pulling the job and the batch running, …
    pub batch_us: u64,
    /// … and the shared forward (merge + engine) for the whole batch.
    pub compute_us: u64,
}

/// Same snapshot ⇒ same bucket (name + version via pointer identity).
fn same_bucket(a: &PredictJob, entry: &Arc<ModelEntry>) -> bool {
    Arc::ptr_eq(&a.entry, entry)
}

/// Pulls jobs off the queue and forms row-bounded, deadline-bounded,
/// single-bucket batches.  One former per inference worker; jobs of a
/// *different* bucket encountered while filling a batch are held over
/// locally and lead the next batch, so nothing is starved.
pub struct BatchFormer {
    queue: Arc<Queue<PredictJob>>,
    held: VecDeque<PredictJob>,
    max_batch: usize,
    max_wait: Duration,
}

impl BatchFormer {
    pub fn new(queue: Arc<Queue<PredictJob>>, max_batch: usize, max_wait: Duration) -> BatchFormer {
        BatchFormer { queue, held: VecDeque::new(), max_batch: max_batch.max(1), max_wait }
    }

    /// Next micro-batch (≥ 1 job, all one bucket), or `None` once the
    /// queue is closed and everything — including held-over jobs — has
    /// been drained.
    pub fn next_batch(&mut self) -> Option<Vec<PredictJob>> {
        let first = match self.held.pop_front() {
            Some(j) => j,
            None => {
                let mut j = self.queue.pop()?;
                j.popped = Some(Instant::now());
                j
            }
        };
        let entry = first.entry.clone();
        let mut rows = first.rows;
        let mut batch = vec![first];
        // held-over jobs from a previous fill get first claim
        let mut i = 0;
        while i < self.held.len() && rows < self.max_batch {
            if same_bucket(&self.held[i], &entry) && rows + self.held[i].rows <= self.max_batch {
                if let Some(j) = self.held.remove(i) {
                    rows += j.rows;
                    batch.push(j);
                }
            } else {
                i += 1;
            }
        }
        let deadline = Instant::now() + self.max_wait;
        while rows < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.queue.pop_timeout(deadline - now) {
                Pop::Item(mut j) => {
                    j.popped = Some(Instant::now());
                    if same_bucket(&j, &entry) && rows + j.rows <= self.max_batch {
                        rows += j.rows;
                        batch.push(j);
                    } else {
                        self.held.push_back(j);
                    }
                }
                Pop::Empty | Pop::Closed => break,
            }
        }
        Some(batch)
    }
}

/// Execute one formed batch and demultiplex the logits.  Engine errors
/// fan out to every waiting job as `Err`, a panicking forward is caught
/// here (every job gets an `Engine` error, the worker thread survives),
/// and jobs whose deadline expired while queued are shed with 503
/// before any compute.  Returns `false` iff the batch panicked — the
/// caller must then discard this model's scratch, which may be torn.
pub fn run_batch(batch: Vec<PredictJob>, scratch: &mut dyn Scratch, metrics: &Metrics) -> bool {
    // deadline shedding: a budget exhausted in the queue means the
    // client has given up (or is about to) — answer 503 now rather
    // than spend a forward on it
    let now = Instant::now();
    let (batch, expired): (Vec<_>, Vec<_>) =
        batch.into_iter().partition(|j| j.deadline.map_or(true, |d| now < d));
    for job in &expired {
        metrics.inc_shed();
        metrics.inc_deadline_exceeded();
        let _ = job
            .reply
            .try_send(Err(ReplyErr::Shed("deadline exceeded while queued".to_string())));
    }
    let Some(entry) = batch.first().map(|j| j.entry.clone()) else {
        return true;
    };
    metrics.observe_batch(batch.iter().map(|j| j.rows).sum());
    // the batch-formation stage of every rider ends here
    let formed = Instant::now();

    // panic isolation: AssertUnwindSafe is sound here because on unwind
    // we answer every job from the still-owned `batch` and the caller
    // discards the (possibly torn) scratch
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        crate::util::fault::check("serve.infer.batch").map_err(|e| e.to_string())?;
        exec_batch(&entry, &batch, scratch, formed)
    }));
    match outcome {
        Ok(Ok(())) => {
            entry.breaker.record_success();
            // harvest the cluster-health stats this batch's forwards
            // accumulated into the per-model /metrics gauges (one
            // relaxed load when the CAST_CLUSTER_STATS gate is off)
            if crate::runtime::native::cluster_stats::active() {
                if let Some(summary) = crate::runtime::native::cluster_stats::take_summary() {
                    metrics.update_cluster_health(&entry.name, summary);
                }
            }
            true
        }
        Ok(Err(msg)) => {
            entry.breaker.record_failure();
            fail_all(&batch, msg);
            true
        }
        Err(_) => {
            metrics.inc_worker_panic();
            entry.breaker.record_failure();
            crate::info!(
                "serve: inference worker panicked mid-batch ({} jobs get 500); worker continues",
                batch.len()
            );
            fail_all(&batch, "inference worker panicked while executing the batch".to_string());
            false
        }
    }
}

/// The fallible compute-and-demux section of [`run_batch`].  On success
/// every job has received its reply; on `Err` nothing was sent and the
/// caller fans the message out.
fn exec_batch(
    entry: &Arc<ModelEntry>,
    batch: &[PredictJob],
    scratch: &mut dyn Scratch,
    formed: Instant,
) -> Result<(), String> {
    let meta = &entry.manifest.meta;
    let n = meta.seq_len;
    let total: usize = batch.iter().map(|j| j.rows).sum();

    // single-job batches (the --max-batch 1 baseline) reuse the job's
    // own tensor; multi-job batches concatenate the padded rows
    let merged: Option<HostTensor> = if batch.len() > 1 {
        let mut data = vec![0i32; total * n];
        let mut off = 0;
        for job in batch {
            let src = job.tokens.as_s32().map_err(|_| "internal: job tokens were not s32")?;
            data[off..off + src.len()].copy_from_slice(src);
            off += src.len();
        }
        Some(HostTensor::s32(vec![total, n], data))
    } else {
        None
    };
    let tokens = merged.as_ref().unwrap_or(&batch[0].tokens);

    let inputs = entry.predict_inputs(tokens);
    let logits = match entry.exe.run_refs_scratch(&inputs, scratch) {
        Ok(mut out) if !out.is_empty() => out.swap_remove(0),
        Ok(_) => return Err("predict returned no outputs".to_string()),
        Err(e) => return Err(format!("predict failed: {e:#}")),
    };
    // one shared forward ⇒ one compute figure for every rider
    let compute_us = formed.elapsed().as_micros() as u64;
    let nc = meta.n_classes;
    let values = match logits.as_f32() {
        Ok(v) if v.len() == total * nc => v,
        Ok(v) => {
            return Err(format!(
                "predict returned {} logits for {} rows x {} classes",
                v.len(),
                total,
                nc
            ))
        }
        Err(e) => return Err(format!("predict output: {e:#}")),
    };
    let mut off = 0;
    for job in batch {
        let span = job.rows * nc;
        // Instant::duration_since saturates to zero, so clock-order
        // surprises degrade to a 0µs stage, never a panic
        let queue_us =
            job.popped.map_or(0, |p| p.duration_since(job.enqueued).as_micros() as u64);
        let batch_us = job.popped.map_or(0, |p| formed.duration_since(p).as_micros() as u64);
        let reply = ReplyOk {
            logits: values[off..off + span].to_vec(),
            n_classes: nc,
            batch_rows: total,
            model: entry.name.clone(),
            version: entry.version,
            queue_us,
            batch_us,
            compute_us,
        };
        off += span;
        // a vanished client (dropped receiver) is not an error, and
        // try_send never blocks on the 1-slot reply channel
        let _ = job.reply.try_send(Ok(reply));
    }
    Ok(())
}

fn fail_all(batch: &[PredictJob], msg: String) {
    for job in batch {
        // try_send: never block on a reply slot that may already hold a
        // response (possible only after a mid-demux panic)
        let _ = job.reply.try_send(Err(ReplyErr::Engine(msg.clone())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::pad_rows;
    use crate::runtime::native::spec::tiny_meta;
    use crate::runtime::Engine;
    use crate::serve::registry::{ModelSource, Registry};
    use crate::util::rng::Rng;
    use std::sync::mpsc::{sync_channel, Receiver};

    fn tiny_entry(reg: &Registry, variant: &str) -> Arc<ModelEntry> {
        reg.load(None, ModelSource::Synthetic { meta: tiny_meta(variant), seed: 3 }).unwrap()
    }

    fn job(entry: &Arc<ModelEntry>, seed: u64) -> (PredictJob, Receiver<Reply>) {
        let n = entry.manifest.meta.seq_len;
        let mut rng = Rng::new(seed);
        let row: Vec<i32> = (0..n).map(|_| rng.below(50) as i32).collect();
        let tokens = pad_rows(&[row], n, 0).unwrap();
        let (tx, rx) = sync_channel(1);
        let j = PredictJob {
            entry: entry.clone(),
            tokens,
            rows: 1,
            reply: tx,
            deadline: None,
            enqueued: Instant::now(),
            popped: None,
        };
        (j, rx)
    }

    #[test]
    fn former_coalesces_up_to_max_batch() {
        let reg = Registry::new(Engine::cpu().unwrap());
        let entry = tiny_entry(&reg, "cast_topk");
        let queue = Arc::new(Queue::bounded(16));
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (j, rx) = job(&entry, i);
            queue.push(j).unwrap();
            rxs.push(rx);
        }
        let mut former = BatchFormer::new(queue.clone(), 8, Duration::from_millis(20));
        let batch = former.next_batch().unwrap();
        assert_eq!(batch.len(), 5, "everything already queued coalesces");
        // cap at max_batch rows
        for i in 0..5 {
            let (j, rx) = job(&entry, 100 + i);
            queue.push(j).unwrap();
            rxs.push(rx);
        }
        let mut capped = BatchFormer::new(queue.clone(), 2, Duration::from_millis(20));
        assert_eq!(capped.next_batch().unwrap().len(), 2);
        assert_eq!(capped.next_batch().unwrap().len(), 2);
        assert_eq!(capped.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn former_separates_buckets_and_drains_after_close() {
        let reg = Registry::new(Engine::cpu().unwrap());
        let a = tiny_entry(&reg, "cast_topk");
        let b = tiny_entry(&reg, "vanilla");
        let queue = Arc::new(Queue::bounded(16));
        let mut rxs = Vec::new();
        for (entry, seed) in [(&a, 1u64), (&b, 2), (&a, 3), (&b, 4)] {
            let (j, rx) = job(entry, seed);
            queue.push(j).unwrap();
            rxs.push(rx);
        }
        queue.close();
        let mut former = BatchFormer::new(queue, 8, Duration::from_millis(5));
        let first = former.next_batch().unwrap();
        assert_eq!(first.len(), 2, "both jobs of bucket A");
        assert!(first.iter().all(|j| Arc::ptr_eq(&j.entry, &a)));
        let second = former.next_batch().unwrap();
        assert_eq!(second.len(), 2, "held-over bucket B jobs");
        assert!(second.iter().all(|j| Arc::ptr_eq(&j.entry, &b)));
        assert!(former.next_batch().is_none(), "closed and drained");
    }

    #[test]
    fn run_batch_demux_matches_individual_predicts() {
        let reg = Registry::new(Engine::cpu().unwrap());
        let entry = tiny_entry(&reg, "cast_topk");
        let metrics = Metrics::new();
        let mut scratch = entry.exe.make_scratch();

        let jobs: Vec<(PredictJob, Receiver<Reply>)> =
            (0..3).map(|i| job(&entry, 1000 + i)).collect();
        // reference: each request alone through the stateless path
        let mut want = Vec::new();
        for (j, _) in &jobs {
            let inputs = entry.predict_inputs(&j.tokens);
            let out = entry.exe.run_refs(&inputs).unwrap();
            want.push(out[0].as_f32().unwrap().to_vec());
        }
        let (batch, rxs): (Vec<_>, Vec<_>) = jobs.into_iter().unzip();
        assert!(run_batch(batch, scratch.as_mut(), &metrics));
        for (rx, want) in rxs.iter().zip(&want) {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got.batch_rows, 3);
            assert_eq!(got.queue_us, 0, "jobs never sat in a queue here");
            assert_eq!(got.batch_us, 0, "no former pulled these jobs");
            assert_eq!(&got.logits, want, "batched logits must equal solo logits exactly");
        }
        assert_eq!(metrics.predict_requests(), 0, "run_batch does not count requests");
        assert_eq!(metrics.batch_rows.count(), 1);
    }

    #[test]
    fn engine_errors_fan_out_to_every_job() {
        let reg = Registry::new(Engine::cpu().unwrap());
        let entry = tiny_entry(&reg, "cast_topk");
        let metrics = Metrics::new();
        let mut scratch = entry.exe.make_scratch();
        // wrong sequence length: the engine rejects the tokens tensor
        let badtok = pad_rows(&[vec![1, 2, 3]], 3, 0).unwrap();
        let (tx1, rx1) = sync_channel(1);
        let (tx2, rx2) = sync_channel(1);
        let mk = |tx| PredictJob {
            entry: entry.clone(),
            tokens: badtok.clone(),
            rows: 1,
            reply: tx,
            deadline: None,
            enqueued: Instant::now(),
            popped: None,
        };
        assert!(run_batch(vec![mk(tx1), mk(tx2)], scratch.as_mut(), &metrics));
        for rx in [rx1, rx2] {
            let err = rx.recv().unwrap().unwrap_err();
            assert!(matches!(err, ReplyErr::Engine(_)), "{err:?}");
            assert!(err.message().contains("predict failed"), "{err:?}");
        }
        assert_eq!(entry.breaker.state_code(), crate::serve::registry::BREAKER_CLOSED);
    }

    #[test]
    fn expired_deadlines_are_shed_not_computed() {
        let reg = Registry::new(Engine::cpu().unwrap());
        let entry = tiny_entry(&reg, "cast_topk");
        let metrics = Metrics::new();
        let mut scratch = entry.exe.make_scratch();
        let (mut expired, rx1) = job(&entry, 1);
        expired.deadline = Some(Instant::now() - Duration::from_millis(5));
        let (live, rx2) = job(&entry, 2);
        assert!(run_batch(vec![expired, live], scratch.as_mut(), &metrics));
        let err = rx1.recv().unwrap().unwrap_err();
        assert!(matches!(err, ReplyErr::Shed(_)), "{err:?}");
        let ok = rx2.recv().unwrap().unwrap();
        assert_eq!(ok.batch_rows, 1, "only the live job was computed");
        assert_eq!(metrics.shed_total(), 1);
        assert_eq!(metrics.deadline_exceeded_total(), 1);
        assert_eq!(metrics.batch_rows.count(), 1, "the shed job never reached a batch");
    }

    #[test]
    fn panicking_batch_answers_every_job_and_worker_survives() {
        let _g = crate::util::fault::test_guard();
        crate::util::fault::set_plan("serve.infer.batch=panic:x1@7");
        let reg = Registry::new(Engine::cpu().unwrap());
        let entry = tiny_entry(&reg, "cast_topk");
        let metrics = Metrics::new();
        let mut scratch = entry.exe.make_scratch();
        let (j1, rx1) = job(&entry, 1);
        let (j2, rx2) = job(&entry, 2);
        let ok = run_batch(vec![j1, j2], scratch.as_mut(), &metrics);
        assert!(!ok, "a panicked batch reports so the caller can drop the scratch");
        assert_eq!(metrics.worker_panics_total(), 1);
        for rx in [rx1, rx2] {
            let err = rx.recv().unwrap().unwrap_err();
            assert!(matches!(err, ReplyErr::Engine(_)), "{err:?}");
            assert!(err.message().contains("panicked"), "{err:?}");
        }
        // the x1 plan is exhausted: the same worker computes fine again
        let (j3, rx3) = job(&entry, 3);
        assert!(run_batch(vec![j3], scratch.as_mut(), &metrics));
        assert!(rx3.recv().unwrap().is_ok());
        crate::util::fault::clear();
    }
}
