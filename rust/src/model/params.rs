//! `ModelState`: parameters, Adam moments, and the step counter — the flat
//! buffer lists whose order is pinned by `manifest.json`.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::{Engine, HostTensor, Manifest};

pub struct ModelState {
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: f32,
}

impl ModelState {
    /// Run the `init` program to materialize fresh parameters.
    pub fn init(engine: &Arc<Engine>, manifest: &Manifest, seed: u32) -> Result<ModelState> {
        let exe = engine.load(manifest, "init")?;
        let seed_t = HostTensor::u32(vec![], vec![seed]);
        let params = exe.run(&[seed_t]).context("running init program")?;
        if params.len() != manifest.n_params() {
            bail!(
                "init returned {} tensors but manifest declares {}",
                params.len(),
                manifest.n_params()
            );
        }
        // cross-check shapes against the manifest contract
        for (t, spec) in params.iter().zip(&manifest.params) {
            if t.shape != spec.shape {
                bail!(
                    "param {:?}: init produced shape {:?}, manifest says {:?}",
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
        }
        Ok(ModelState::from_params(params))
    }

    /// Wrap existing parameters (e.g. from a checkpoint) with zeroed moments.
    pub fn from_params(params: Vec<HostTensor>) -> ModelState {
        let m = params.iter().map(|p| HostTensor::zeros(p.dtype(), p.shape.clone())).collect();
        let v = params.iter().map(|p| HostTensor::zeros(p.dtype(), p.shape.clone())).collect();
        ModelState { params, m, v, step: 0.0 }
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_elems(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Global L2 norm of the parameters (training sanity metric).
    pub fn param_norm(&self) -> f64 {
        let mut acc = 0.0f64;
        for p in &self.params {
            if let Ok(v) = p.as_f32() {
                acc += v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
            }
        }
        acc.sqrt()
    }

    /// Assemble the train_step input list by reference (hot path):
    /// params ++ m ++ v ++ [step, lr] ++ [tokens, labels].  The scalar
    /// tensors are owned by the caller (`scalars`).
    pub fn train_inputs_refs<'a>(
        &'a self,
        scalars: &'a (HostTensor, HostTensor),
        tokens: &'a HostTensor,
        labels: &'a HostTensor,
    ) -> Vec<&'a HostTensor> {
        let mut inputs: Vec<&HostTensor> = Vec::with_capacity(3 * self.params.len() + 4);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.push(&scalars.0);
        inputs.push(&scalars.1);
        inputs.push(tokens);
        inputs.push(labels);
        inputs
    }

    /// Assemble the train_step input list:
    /// params ++ m ++ v ++ [step, lr] ++ [tokens, labels].
    pub fn train_inputs(
        &self,
        lr: f32,
        tokens: HostTensor,
        labels: HostTensor,
    ) -> Vec<HostTensor> {
        let mut inputs =
            Vec::with_capacity(3 * self.params.len() + 4);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());
        inputs.push(HostTensor::scalar_f32(self.step));
        inputs.push(HostTensor::scalar_f32(lr));
        inputs.push(tokens);
        inputs.push(labels);
        inputs
    }

    /// Absorb train_step outputs: params' ++ m' ++ v' ++ [step', loss, acc].
    /// Returns (loss, acc).
    pub fn absorb(&mut self, mut outputs: Vec<HostTensor>) -> Result<(f32, f32)> {
        let p = self.params.len();
        if outputs.len() != 3 * p + 3 {
            bail!("train_step returned {} outputs, expected {}", outputs.len(), 3 * p + 3);
        }
        let acc = outputs.pop().unwrap().scalar()?;
        let loss = outputs.pop().unwrap().scalar()?;
        let step = outputs.pop().unwrap().scalar()?;
        self.v = outputs.split_off(2 * p);
        self.m = outputs.split_off(p);
        self.params = outputs;
        self.step = step;
        Ok((loss, acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_state(n: usize) -> ModelState {
        let params = (0..n)
            .map(|i| HostTensor::f32(vec![2], vec![i as f32, i as f32 + 0.5]))
            .collect();
        ModelState::from_params(params)
    }

    #[test]
    fn train_inputs_layout() {
        let st = fake_state(3);
        let tok = HostTensor::s32(vec![1, 4], vec![1, 2, 3, 4]);
        let lab = HostTensor::s32(vec![1], vec![0]);
        let inputs = st.train_inputs(0.01, tok, lab);
        assert_eq!(inputs.len(), 3 * 3 + 4);
        assert_eq!(inputs[9].scalar().unwrap(), 0.0); // step
        assert_eq!(inputs[10].scalar().unwrap(), 0.01); // lr
    }

    #[test]
    fn train_inputs_refs_matches_owned_layout() {
        let st = fake_state(3);
        let tok = HostTensor::s32(vec![1, 4], vec![1, 2, 3, 4]);
        let lab = HostTensor::s32(vec![1], vec![0]);
        let scalars = (HostTensor::scalar_f32(st.step), HostTensor::scalar_f32(0.01));
        let by_ref = st.train_inputs_refs(&scalars, &tok, &lab);
        let owned = st.train_inputs(0.01, tok.clone(), lab.clone());
        assert_eq!(by_ref.len(), owned.len());
        for (r, o) in by_ref.iter().zip(&owned) {
            assert_eq!(r.shape, o.shape);
        }
        assert_eq!(by_ref[10].scalar().unwrap(), 0.01);
    }

    #[test]
    fn absorb_roundtrip() {
        let mut st = fake_state(2);
        let outs = vec![
            HostTensor::f32(vec![2], vec![9.0, 9.0]),
            HostTensor::f32(vec![2], vec![8.0, 8.0]),
            HostTensor::f32(vec![2], vec![7.0, 7.0]),
            HostTensor::f32(vec![2], vec![6.0, 6.0]),
            HostTensor::f32(vec![2], vec![5.0, 5.0]),
            HostTensor::f32(vec![2], vec![4.0, 4.0]),
            HostTensor::scalar_f32(1.0),
            HostTensor::scalar_f32(0.25),
            HostTensor::scalar_f32(0.75),
        ];
        let (loss, acc) = st.absorb(outs).unwrap();
        assert_eq!((loss, acc), (0.25, 0.75));
        assert_eq!(st.step, 1.0);
        assert_eq!(st.params[0].as_f32().unwrap(), &[9.0, 9.0]);
        assert_eq!(st.m[1].as_f32().unwrap(), &[6.0, 6.0]);
        assert_eq!(st.v[1].as_f32().unwrap(), &[4.0, 4.0]);
    }

    #[test]
    fn absorb_wrong_arity_errors() {
        let mut st = fake_state(2);
        assert!(st.absorb(vec![HostTensor::scalar_f32(0.0)]).is_err());
    }

    #[test]
    fn param_norm_positive() {
        assert!(fake_state(2).param_norm() > 0.0);
    }
}
