//! Checkpoint format: `CAST0001` magic, a JSON header (param specs + step),
//! then raw little-endian f32/s32 tensor payloads in manifest order.
//!
//! Layout:
//!   [8]  magic  b"CAST0001"
//!   [8]  header length (LE u64)
//!   [..] header JSON
//!   [..] payloads, each tensor's bytes back-to-back (sizes from header)

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{DType, HostTensor};
use crate::util::json::Json;

use super::params::ModelState;

const MAGIC: &[u8; 8] = b"CAST0001";
/// Sanity caps applied while loading: a corrupt or truncated file must
/// surface as a proper error (the serve registry rejects the upload),
/// never as a panic or an absurd allocation.
const MAX_HEADER_BYTES: usize = 64 << 20;
const MAX_TENSOR_ELEMS: usize = 1 << 31;

pub fn save(state: &ModelState, names: &[String], path: &Path) -> Result<()> {
    if names.len() != state.params.len() {
        bail!("names/params length mismatch");
    }
    let mut entries = Vec::new();
    for (name, t) in names.iter().zip(&state.params) {
        entries.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("shape", Json::arr_usize(&t.shape)),
            ("dtype", Json::str(t.dtype().name())),
        ]));
    }
    let header = Json::obj(vec![
        ("step", Json::num(state.step as f64)),
        ("params", Json::Arr(entries)),
    ])
    .to_string();

    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    // params, then adam moments (so training can resume exactly)
    for group in [&state.params, &state.m, &state.v] {
        for t in group.iter() {
            f.write_all(tensor_bytes(t))?;
        }
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<(ModelState, Vec<String>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?} is not a CAST checkpoint (bad magic)");
    }
    let mut len_bytes = [0u8; 8];
    f.read_exact(&mut len_bytes)?;
    let header_len = u64::from_le_bytes(len_bytes) as usize;
    // cap before allocating: a corrupt length field must not trigger a
    // multi-GB allocation
    if header_len > MAX_HEADER_BYTES {
        bail!(
            "{path:?} is corrupt: header length {header_len} exceeds the {MAX_HEADER_BYTES}-byte cap"
        );
    }
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = Json::parse(std::str::from_utf8(&header)?)?;

    let step = header.get("step").and_then(Json::as_f64).context("header step")? as f32;
    let specs = header.get("params").and_then(Json::as_arr).context("header params")?;

    let mut names = Vec::new();
    let mut shapes: Vec<(Vec<usize>, DType)> = Vec::new();
    for s in specs {
        let name = s.get("name").and_then(Json::as_str).context("header param name")?;
        let mut shape = Vec::new();
        for d in s.get("shape").and_then(Json::as_arr).with_context(|| format!("header shape for {name:?}"))? {
            shape.push(parse_dim(d).with_context(|| format!("header shape for {name:?}"))?);
        }
        let elems = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .unwrap_or(usize::MAX);
        if elems > MAX_TENSOR_ELEMS {
            bail!("{path:?} is corrupt: {name:?} shape {shape:?} exceeds the element cap");
        }
        let dtype = DType::parse(s.get("dtype").and_then(Json::as_str).context("header dtype")?)?;
        names.push(name.to_string());
        shapes.push((shape, dtype));
    }

    // before allocating any payload buffer, check the header's declared
    // sizes against the actual file length — a corrupt header must not
    // trigger a multi-GB zero-fill, and truncation surfaces up front
    let declared: u64 = shapes
        .iter()
        .map(|(shape, _)| 4 * shape.iter().map(|&d| d as u64).product::<u64>())
        .sum::<u64>()
        * 3; // params + m + v
    let expected = 8 + 8 + header_len as u64 + declared;
    let file_len = std::fs::metadata(path)?.len();
    if file_len < expected {
        bail!(
            "{path:?} is corrupt or truncated: {file_len} bytes on disk, header declares {expected}"
        );
    }

    let mut read_group = |f: &mut dyn Read| -> Result<Vec<HostTensor>> {
        shapes
            .iter()
            .map(|(shape, dtype)| {
                let n: usize = shape.iter().product();
                let mut buf = vec![0u8; n * 4];
                f.read_exact(&mut buf)?;
                Ok(match dtype {
                    DType::F32 => HostTensor::f32(shape.clone(), le_f32(&buf)),
                    DType::S32 => HostTensor::s32(shape.clone(), le_s32(&buf)),
                    DType::U32 => {
                        let v = le_s32(&buf).into_iter().map(|x| x as u32).collect();
                        HostTensor::u32(shape.clone(), v)
                    }
                })
            })
            .collect()
    };

    let params = read_group(&mut f)?;
    let m = read_group(&mut f)?;
    let v = read_group(&mut f)?;
    let mut state = ModelState { params, m, v, step };
    // tolerate truncated moments (older checkpoints): re-zero
    if state.m.len() != state.params.len() {
        state = ModelState::from_params(state.params);
    }
    Ok((state, names))
}

/// Parse one shape dimension from the checkpoint header, rejecting the
/// values a corrupt file can smuggle through the f64-backed JSON layer
/// (negatives, fractions, non-numbers) instead of panicking.
fn parse_dim(d: &Json) -> Result<usize> {
    let n = d.as_f64().context("shape dim is not a number")?;
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > MAX_TENSOR_ELEMS as f64 {
        bail!("shape dim {n} is not a valid tensor dimension");
    }
    Ok(n as usize)
}

fn tensor_bytes(t: &HostTensor) -> &[u8] {
    use crate::runtime::Data;
    match &t.data {
        Data::F32(v) => unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
        },
        Data::S32(v) => unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
        },
        Data::U32(v) => unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
        },
    }
}

fn le_f32(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn le_s32(bytes: &[u8]) -> Vec<i32> {
    bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let params = vec![
            HostTensor::f32(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]),
            HostTensor::f32(vec![3], vec![9.0, 8.0, 7.0]),
        ];
        let mut state = ModelState::from_params(params);
        state.step = 42.0;
        state.m[0] = HostTensor::f32(vec![2, 2], vec![0.1, 0.2, 0.3, 0.4]);
        let names = vec!["w".to_string(), "b".to_string()];

        let dir = std::env::temp_dir().join("cast_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.ckpt");
        save(&state, &names, &path).unwrap();

        let (loaded, lnames) = load(&path).unwrap();
        assert_eq!(lnames, names);
        assert_eq!(loaded.step, 42.0);
        assert_eq!(loaded.params[0].as_f32().unwrap(), state.params[0].as_f32().unwrap());
        assert_eq!(loaded.m[0].as_f32().unwrap(), &[0.1, 0.2, 0.3, 0.4]);
        assert_eq!(loaded.v[1].as_f32().unwrap(), &[0.0, 0.0, 0.0]);
    }

    /// Assemble a file with valid magic + the given header JSON text.
    fn write_with_header(path: &std::path::Path, header: &str, payload: &[u8]) {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(payload);
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn corrupt_shapes_error_instead_of_panicking() {
        let dir = std::env::temp_dir().join("cast_ckpt_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_shape.ckpt");
        for bad in [
            r#"{"step":0,"params":[{"name":"w","shape":["x",2],"dtype":"f32"}]}"#,
            r#"{"step":0,"params":[{"name":"w","shape":[-4],"dtype":"f32"}]}"#,
            r#"{"step":0,"params":[{"name":"w","shape":[2.5],"dtype":"f32"}]}"#,
            r#"{"step":0,"params":[{"name":"w","shape":[1e18],"dtype":"f32"}]}"#,
            r#"{"step":0,"params":[{"name":"w","shape":{"not":"arr"},"dtype":"f32"}]}"#,
        ] {
            write_with_header(&path, bad, &[]);
            assert!(load(&path).is_err(), "header {bad} must be rejected");
        }
    }

    #[test]
    fn huge_declared_shape_errors_before_allocating() {
        let dir = std::env::temp_dir().join("cast_ckpt_huge_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("huge.ckpt");
        // a ~4 GiB declared tensor in a tiny file must fail the
        // file-length check up front, not zero-fill gigabytes first
        write_with_header(
            &path,
            r#"{"step":0,"params":[{"name":"w","shape":[1073741824],"dtype":"f32"}]}"#,
            &[0u8; 16],
        );
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let dir = std::env::temp_dir().join("cast_ckpt_trunc_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.ckpt");
        // header declares 3 f32s; payload carries only one
        write_with_header(
            &path,
            r#"{"step":0,"params":[{"name":"w","shape":[3],"dtype":"f32"}]}"#,
            &[0u8; 4],
        );
        assert!(load(&path).is_err());
    }

    #[test]
    fn absurd_header_length_is_an_error() {
        let dir = std::env::temp_dir().join("cast_ckpt_hdrlen_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hdrlen.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("header length"), "{err:#}");
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("cast_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxx").unwrap();
        assert!(load(&path).is_err());
    }
}
