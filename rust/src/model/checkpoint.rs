//! Checkpoint format: `CAST0002` magic, a JSON header (param specs + step),
//! raw little-endian f32/s32 tensor payloads in manifest order, and an
//! FNV-1a-64 digest trailer over everything before it.
//!
//! Layout:
//!   [8]  magic  b"CAST0002"
//!   [8]  header length (LE u64)
//!   [..] header JSON
//!   [..] payloads, each tensor's bytes back-to-back (sizes from header)
//!   [8]  FNV-1a-64 digest of all preceding bytes (LE u64)
//!
//! Writes are atomic (DESIGN.md §Robustness): the full image is
//! serialized in memory, written to `<path>.tmp`, fsynced, the previous
//! good checkpoint is rotated to `<path>.prev`, and the tmp file is
//! renamed into place — a crash at any point leaves at least one
//! digest-valid file for `load_auto` to find.  Transient IO goes
//! through `util::retry` deterministic exponential backoff, and the
//! `ckpt.*` fault points (`util::fault`) make every failure path
//! testable.  Legacy `CAST0001` files (no trailer) still load.

use std::io::{self, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::{DType, HostTensor};
use crate::util::json::Json;
use crate::util::{fault, retry};

use super::params::ModelState;

const MAGIC: &[u8; 8] = b"CAST0002";
const LEGACY_MAGIC: &[u8; 8] = b"CAST0001";
/// Sanity caps applied while loading: a corrupt or truncated file must
/// surface as a proper error (the serve registry rejects the upload),
/// never as a panic or an absurd allocation.
const MAX_HEADER_BYTES: usize = 64 << 20;
const MAX_TENSOR_ELEMS: usize = 1 << 31;

/// The rotation slot a successful `save` moves the previous good
/// checkpoint into, and the fallback `load_auto` scans.
pub fn prev_path(path: &Path) -> PathBuf {
    sibling(path, ".prev")
}

fn tmp_path(path: &Path) -> PathBuf {
    sibling(path, ".tmp")
}

fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(suffix);
    path.with_file_name(name)
}

pub fn save(state: &ModelState, names: &[String], path: &Path) -> Result<()> {
    let bytes = encode(state, names)?;
    let tmp = tmp_path(path);
    retry::with_backoff("checkpoint write", retry::Backoff::io(), || {
        fault::check("ckpt.save.io")?;
        write_durable(&tmp, &bytes)
    })
    .with_context(|| format!("writing {tmp:?}"))?;
    // rotate the previous good checkpoint to <path>.prev *before* the
    // final rename: a crash between the two renames leaves no <path>,
    // but .prev is still digest-valid and load_auto falls back to it
    if path.exists() {
        let _ = std::fs::rename(path, prev_path(path));
    }
    retry::with_backoff("checkpoint rename", retry::Backoff::io(), || {
        fault::check("ckpt.save.rename")?;
        std::fs::rename(&tmp, path)
    })
    .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Write the full byte image to `path` and fsync it, honoring the
/// `ckpt.save.torn` fault point (a torn write persists a prefix of the
/// bytes, then fails the way a crashed writer would).
fn write_durable(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    if let Some(n) = fault::torn_len("ckpt.save.torn", bytes.len()) {
        f.write_all(&bytes[..n])?;
        f.sync_all()?;
        return Err(io::Error::other(format!("injected torn write ({n}/{} bytes)", bytes.len())));
    }
    f.write_all(bytes)?;
    // fsync before rename: rename-atomicity only helps if the bytes
    // behind the new name are already durable
    f.sync_all()?;
    Ok(())
}

fn encode(state: &ModelState, names: &[String]) -> Result<Vec<u8>> {
    if names.len() != state.params.len() {
        bail!("names/params length mismatch");
    }
    let mut entries = Vec::new();
    for (name, t) in names.iter().zip(&state.params) {
        entries.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("shape", Json::arr_usize(&t.shape)),
            ("dtype", Json::str(t.dtype().name())),
        ]));
    }
    let header = Json::obj(vec![
        ("step", Json::num(state.step as f64)),
        ("params", Json::Arr(entries)),
    ])
    .to_string();

    let mut bytes = Vec::with_capacity(24 + header.len());
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
    bytes.extend_from_slice(header.as_bytes());
    // params, then adam moments (so training can resume exactly)
    for group in [&state.params, &state.m, &state.v] {
        for t in group.iter() {
            bytes.extend_from_slice(tensor_bytes(t));
        }
    }
    let digest = fnv1a64(&bytes);
    bytes.extend_from_slice(&digest.to_le_bytes());
    Ok(bytes)
}

pub fn load(path: &Path) -> Result<(ModelState, Vec<String>)> {
    let bytes = retry::with_backoff("checkpoint read", retry::Backoff::io(), || {
        fault::check("ckpt.load.io")?;
        std::fs::read(path)
    })
    .with_context(|| format!("opening {path:?}"))?;
    decode(&bytes, path)
}

/// Scan backward through the checkpoint rotation (`path`, then
/// `<path>.prev`) and load the first digest-valid file.  Returns the
/// path actually loaded so callers can log which generation resumed.
pub fn load_auto(path: &Path) -> Result<(ModelState, Vec<String>, PathBuf)> {
    let candidates = [path.to_path_buf(), prev_path(path)];
    let mut last_err = None;
    for cand in &candidates {
        if !cand.exists() {
            continue;
        }
        match load(cand) {
            Ok((state, names)) => {
                if cand != path {
                    crate::info!("checkpoint: {path:?} invalid, falling back to {cand:?}");
                }
                return Ok((state, names, cand.clone()));
            }
            Err(e) => {
                crate::info!("checkpoint: skipping {cand:?}: {e:#}");
                last_err = Some(e);
            }
        }
    }
    match last_err {
        Some(e) => Err(e.context(format!("no digest-valid checkpoint at {path:?}"))),
        None => bail!("no checkpoint found at {path:?}"),
    }
}

fn decode(bytes: &[u8], path: &Path) -> Result<(ModelState, Vec<String>)> {
    if bytes.len() < 16 {
        bail!("{path:?} is not a CAST checkpoint (too short)");
    }
    let legacy = &bytes[..8] == LEGACY_MAGIC.as_slice();
    if !legacy && &bytes[..8] != MAGIC.as_slice() {
        bail!("{path:?} is not a CAST checkpoint (bad magic)");
    }
    let body = if legacy {
        bytes
    } else {
        if bytes.len() < 24 {
            bail!("{path:?} is corrupt or truncated: no room for the digest trailer");
        }
        let (body, trailer) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().unwrap());
        let computed = fnv1a64(body);
        if stored != computed {
            bail!(
                "{path:?} is corrupt: digest mismatch (stored {stored:016x}, computed {computed:016x})"
            );
        }
        body
    };

    let header_len = u64::from_le_bytes(body[8..16].try_into().unwrap()) as usize;
    // cap before allocating: a corrupt length field must not trigger a
    // multi-GB allocation
    if header_len > MAX_HEADER_BYTES {
        bail!(
            "{path:?} is corrupt: header length {header_len} exceeds the {MAX_HEADER_BYTES}-byte cap"
        );
    }
    if body.len() < 16 + header_len {
        bail!("{path:?} is corrupt or truncated: header overruns the file");
    }
    let header = Json::parse(std::str::from_utf8(&body[16..16 + header_len])?)?;

    let step = header.get("step").and_then(Json::as_f64).context("header step")? as f32;
    let specs = header.get("params").and_then(Json::as_arr).context("header params")?;

    let mut names = Vec::new();
    let mut shapes: Vec<(Vec<usize>, DType)> = Vec::new();
    for s in specs {
        let name = s.get("name").and_then(Json::as_str).context("header param name")?;
        let mut shape = Vec::new();
        for d in s.get("shape").and_then(Json::as_arr).with_context(|| format!("header shape for {name:?}"))? {
            shape.push(parse_dim(d).with_context(|| format!("header shape for {name:?}"))?);
        }
        let elems = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .unwrap_or(usize::MAX);
        if elems > MAX_TENSOR_ELEMS {
            bail!("{path:?} is corrupt: {name:?} shape {shape:?} exceeds the element cap");
        }
        let dtype = DType::parse(s.get("dtype").and_then(Json::as_str).context("header dtype")?)?;
        names.push(name.to_string());
        shapes.push((shape, dtype));
    }

    // before touching any payload, check the header's declared sizes
    // against the actual byte count — a corrupt header must not trigger
    // a multi-GB zero-fill, and truncation surfaces up front
    let payload = &body[16 + header_len..];
    let declared: u64 = shapes
        .iter()
        .map(|(shape, _)| 4 * shape.iter().map(|&d| d as u64).product::<u64>())
        .sum::<u64>()
        * 3; // params + m + v
    if (payload.len() as u64) < declared {
        bail!(
            "{path:?} is corrupt or truncated: {} payload bytes on disk, header declares {declared}",
            payload.len()
        );
    }

    let mut off = 0usize;
    let params = read_group(payload, &mut off, &shapes)?;
    let m = read_group(payload, &mut off, &shapes)?;
    let v = read_group(payload, &mut off, &shapes)?;
    Ok((ModelState { params, m, v, step }, names))
}

fn read_group(
    payload: &[u8],
    off: &mut usize,
    shapes: &[(Vec<usize>, DType)],
) -> Result<Vec<HostTensor>> {
    shapes
        .iter()
        .map(|(shape, dtype)| {
            let n: usize = shape.iter().product();
            let end = *off + n * 4;
            anyhow::ensure!(end <= payload.len(), "payload overruns the file");
            let buf = &payload[*off..end];
            *off = end;
            Ok(match dtype {
                DType::F32 => HostTensor::f32(shape.clone(), le_f32(buf)),
                DType::S32 => HostTensor::s32(shape.clone(), le_s32(buf)),
                DType::U32 => {
                    let v = le_s32(buf).into_iter().map(|x| x as u32).collect();
                    HostTensor::u32(shape.clone(), v)
                }
            })
        })
        .collect()
}

/// Parse one shape dimension from the checkpoint header, rejecting the
/// values a corrupt file can smuggle through the f64-backed JSON layer
/// (negatives, fractions, non-numbers) instead of panicking.
fn parse_dim(d: &Json) -> Result<usize> {
    let n = d.as_f64().context("shape dim is not a number")?;
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > MAX_TENSOR_ELEMS as f64 {
        bail!("shape dim {n} is not a valid tensor dimension");
    }
    Ok(n as usize)
}

/// FNV-1a 64 over the byte image — a dependency-free digest for the
/// trailer.  Not cryptographic: it guards against truncation, bit rot,
/// and torn writes, not adversaries (content-addressed manifests with a
/// real hash are a ROADMAP item).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn tensor_bytes(t: &HostTensor) -> &[u8] {
    use crate::runtime::Data;
    match &t.data {
        Data::F32(v) => unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
        },
        Data::S32(v) => unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
        },
        Data::U32(v) => unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
        },
    }
}

fn le_f32(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn le_s32(bytes: &[u8]) -> Vec<i32> {
    bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_state(step: f32, seed: f32) -> (ModelState, Vec<String>) {
        let params = vec![
            HostTensor::f32(vec![2, 2], vec![seed, -2.0 * seed, 3.5, 0.0]),
            HostTensor::f32(vec![3], vec![9.0 + seed, 8.0, 7.0]),
        ];
        let mut state = ModelState::from_params(params);
        state.step = step;
        state.m[0] = HostTensor::f32(vec![2, 2], vec![0.1 * seed, 0.2, 0.3, 0.4]);
        (state, vec!["w".to_string(), "b".to_string()])
    }

    fn fresh_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip() {
        let (state, names) = test_state(42.0, 1.0);
        let dir = fresh_dir("cast_ckpt_test");
        let path = dir.join("model.ckpt");
        save(&state, &names, &path).unwrap();

        let (loaded, lnames) = load(&path).unwrap();
        assert_eq!(lnames, names);
        assert_eq!(loaded.step, 42.0);
        assert_eq!(loaded.params[0].as_f32().unwrap(), state.params[0].as_f32().unwrap());
        assert_eq!(loaded.m[0].as_f32().unwrap(), &[0.1, 0.2, 0.3, 0.4]);
        assert_eq!(loaded.v[1].as_f32().unwrap(), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn save_leaves_no_tmp_file_behind() {
        let (state, names) = test_state(1.0, 1.0);
        let dir = fresh_dir("cast_ckpt_atomic_test");
        let path = dir.join("model.ckpt");
        save(&state, &names, &path).unwrap();
        assert!(path.exists());
        assert!(!tmp_path(&path).exists(), "tmp file must be renamed away");
    }

    #[test]
    fn digest_rejects_bit_flip() {
        let (state, names) = test_state(7.0, 2.0);
        let dir = fresh_dir("cast_ckpt_bitflip_test");
        let path = dir.join("model.ckpt");
        save(&state, &names, &path).unwrap();

        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("digest"), "{err:#}");
    }

    #[test]
    fn digest_rejects_truncation() {
        let (state, names) = test_state(7.0, 3.0);
        let dir = fresh_dir("cast_ckpt_digtrunc_test");
        let path = dir.join("model.ckpt");
        save(&state, &names, &path).unwrap();

        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load(&path).is_err(), "truncated file must be rejected");
    }

    #[test]
    fn rotation_keeps_prev_and_load_auto_falls_back_bit_identically() {
        let (state1, names) = test_state(1.0, 1.0);
        let (state2, _) = test_state(2.0, 5.0);
        let dir = fresh_dir("cast_ckpt_auto_test");
        let path = dir.join("model.ckpt");

        save(&state1, &names, &path).unwrap();
        save(&state2, &names, &path).unwrap();
        assert!(prev_path(&path).exists(), "second save must rotate the first to .prev");

        // intact primary wins
        let (got, _, from) = load_auto(&path).unwrap();
        assert_eq!(from, path);
        assert_eq!(got.step, 2.0);

        // corrupt the primary: load_auto must fall back to .prev and
        // restore state1 bit-identically, moments included
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let (got, gnames, from) = load_auto(&path).unwrap();
        assert_eq!(from, prev_path(&path));
        assert_eq!(gnames, names);
        assert_eq!(got.step, 1.0);
        for (a, b) in got.params.iter().zip(&state1.params) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        for (a, b) in got.m.iter().zip(&state1.m) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        for (a, b) in got.v.iter().zip(&state1.v) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn load_auto_errors_when_everything_is_corrupt() {
        let (state, names) = test_state(1.0, 1.0);
        let dir = fresh_dir("cast_ckpt_allbad_test");
        let path = dir.join("model.ckpt");
        save(&state, &names, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..20]).unwrap();
        let err = load_auto(&path).unwrap_err();
        assert!(format!("{err:#}").contains("no digest-valid checkpoint"), "{err:#}");
    }

    #[test]
    fn legacy_cast0001_still_loads() {
        // one [2] f32 param: header + 3 groups of 8 payload bytes, no trailer
        let header = r#"{"step":3,"params":[{"name":"w","shape":[2],"dtype":"f32"}]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(LEGACY_MAGIC);
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for x in [1.5f32, -2.5, 0.0, 0.0, 0.0, 0.0] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let dir = fresh_dir("cast_ckpt_legacy_test");
        let path = dir.join("legacy.ckpt");
        std::fs::write(&path, bytes).unwrap();
        let (state, names) = load(&path).unwrap();
        assert_eq!(names, vec!["w".to_string()]);
        assert_eq!(state.step, 3.0);
        assert_eq!(state.params[0].as_f32().unwrap(), &[1.5, -2.5]);
    }

    /// Assemble a file with valid magic + digest around the given header
    /// JSON text, so the inner header validations are what's exercised.
    fn write_with_header(path: &std::path::Path, header: &str, payload: &[u8]) {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(payload);
        let digest = fnv1a64(&bytes);
        bytes.extend_from_slice(&digest.to_le_bytes());
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn corrupt_shapes_error_instead_of_panicking() {
        let dir = fresh_dir("cast_ckpt_corrupt_test");
        let path = dir.join("bad_shape.ckpt");
        for bad in [
            r#"{"step":0,"params":[{"name":"w","shape":["x",2],"dtype":"f32"}]}"#,
            r#"{"step":0,"params":[{"name":"w","shape":[-4],"dtype":"f32"}]}"#,
            r#"{"step":0,"params":[{"name":"w","shape":[2.5],"dtype":"f32"}]}"#,
            r#"{"step":0,"params":[{"name":"w","shape":[1e18],"dtype":"f32"}]}"#,
            r#"{"step":0,"params":[{"name":"w","shape":{"not":"arr"},"dtype":"f32"}]}"#,
        ] {
            write_with_header(&path, bad, &[]);
            assert!(load(&path).is_err(), "header {bad} must be rejected");
        }
    }

    #[test]
    fn huge_declared_shape_errors_before_allocating() {
        let dir = fresh_dir("cast_ckpt_huge_test");
        let path = dir.join("huge.ckpt");
        // a ~4 GiB declared tensor in a tiny file must fail the
        // length check up front, not zero-fill gigabytes first
        write_with_header(
            &path,
            r#"{"step":0,"params":[{"name":"w","shape":[1073741824],"dtype":"f32"}]}"#,
            &[0u8; 16],
        );
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let dir = fresh_dir("cast_ckpt_trunc_test");
        let path = dir.join("trunc.ckpt");
        // header declares 3 f32s; payload carries only one
        write_with_header(
            &path,
            r#"{"step":0,"params":[{"name":"w","shape":[3],"dtype":"f32"}]}"#,
            &[0u8; 4],
        );
        assert!(load(&path).is_err());
    }

    #[test]
    fn absurd_header_length_is_an_error() {
        let dir = fresh_dir("cast_ckpt_hdrlen_test");
        let path = dir.join("hdrlen.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        let digest = fnv1a64(&bytes);
        bytes.extend_from_slice(&digest.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("header length"), "{err:#}");
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = fresh_dir("cast_ckpt_test2");
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxx").unwrap();
        assert!(load(&path).is_err());
    }
}
