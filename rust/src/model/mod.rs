//! Model state owned by the coordinator: parameters + Adam moments as raw
//! host buffers, created by the `init` artifact and threaded through
//! `train_step` executions.  Includes the on-disk checkpoint format.

pub mod checkpoint;
pub mod params;

pub use params::ModelState;
