//! Training metrics: per-step records, running means, and export to
//! JSON/CSV for experiment reports and the loss-curve artifacts.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub seconds: f64,
}

#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub step: usize,
    pub acc: f32,
    pub loss: f32,
}

#[derive(Default, Debug)]
pub struct History {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
}

impl History {
    pub fn push_step(&mut self, r: StepRecord) {
        self.steps.push(r);
    }

    pub fn push_eval(&mut self, r: EvalRecord) {
        self.evals.push(r);
    }

    /// Mean training loss over the trailing `window` steps.
    pub fn recent_loss(&self, window: usize) -> f32 {
        let n = self.steps.len();
        if n == 0 {
            return f32::NAN;
        }
        let lo = n.saturating_sub(window);
        let slice = &self.steps[lo..];
        slice.iter().map(|r| r.loss).sum::<f32>() / slice.len() as f32
    }

    pub fn recent_acc(&self, window: usize) -> f32 {
        let n = self.steps.len();
        if n == 0 {
            return f32::NAN;
        }
        let lo = n.saturating_sub(window);
        let slice = &self.steps[lo..];
        slice.iter().map(|r| r.acc).sum::<f32>() / slice.len() as f32
    }

    pub fn best_eval_acc(&self) -> Option<f32> {
        self.evals.iter().map(|e| e.acc).fold(None, |best, a| {
            Some(best.map_or(a, |b: f32| b.max(a)))
        })
    }

    /// Mean steps/second over the whole run (excludes eval time).
    pub fn steps_per_sec(&self) -> f64 {
        let total: f64 = self.steps.iter().map(|r| r.seconds).sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.steps.len() as f64 / total
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "steps",
                Json::Arr(
                    self.steps
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("step", Json::num(r.step as f64)),
                                ("loss", Json::num(r.loss as f64)),
                                ("acc", Json::num(r.acc as f64)),
                                ("lr", Json::num(r.lr as f64)),
                                ("seconds", Json::num(r.seconds)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("step", Json::num(r.step as f64)),
                                ("acc", Json::num(r.acc as f64)),
                                ("loss", Json::num(r.loss as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("steps_per_sec", Json::num(self.steps_per_sec())),
        ])
    }

    pub fn save_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::from("step,loss,acc,lr,seconds\n");
        for r in &self.steps {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                r.step, r.loss, r.acc, r.lr, r.seconds
            ));
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32) -> StepRecord {
        StepRecord { step, loss, acc: 0.5, lr: 1e-3, seconds: 0.1 }
    }

    #[test]
    fn recent_loss_windows() {
        let mut h = History::default();
        for i in 0..10 {
            h.push_step(rec(i, i as f32));
        }
        assert_eq!(h.recent_loss(2), 8.5);
        assert_eq!(h.recent_loss(100), 4.5);
    }

    #[test]
    fn steps_per_sec() {
        let mut h = History::default();
        for i in 0..5 {
            h.push_step(rec(i, 1.0));
        }
        assert!((h.steps_per_sec() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn best_eval() {
        let mut h = History::default();
        assert_eq!(h.best_eval_acc(), None);
        h.push_eval(EvalRecord { step: 1, acc: 0.4, loss: 1.0 });
        h.push_eval(EvalRecord { step: 2, acc: 0.7, loss: 0.8 });
        h.push_eval(EvalRecord { step: 3, acc: 0.6, loss: 0.9 });
        assert_eq!(h.best_eval_acc(), Some(0.7));
    }

    #[test]
    fn json_roundtrips() {
        let mut h = History::default();
        h.push_step(rec(0, 2.0));
        let j = h.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.path("steps").unwrap().as_arr().unwrap().len(), 1);
    }
}
