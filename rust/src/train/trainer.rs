//! The training loop: drives the `train_step` artifact over the background
//! batch pipeline, schedules the learning rate, runs held-out evaluation
//! through the `predict` artifact, and records metrics.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::batcher::Batcher;
use crate::data::{self, Batch, TaskGen};
use crate::model::{checkpoint, ModelState};
use crate::runtime::native::cluster_stats;
use crate::runtime::{Engine, Executable, HostTensor, Manifest};
use crate::util::json::Json;
use crate::util::{trace, Timer};

use super::metrics::{EvalRecord, History, StepRecord};
use super::schedule::Schedule;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub schedule: Schedule,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub data_workers: usize,
    pub queue_depth: usize,
    pub log_every: usize,
    pub checkpoint: Option<PathBuf>,
    /// Save a rotating checkpoint every N steps (0 = final save only).
    /// Each save is atomic and keeps the previous generation as
    /// `<ckpt>.prev`, so a crash mid-write never loses resumability.
    pub ckpt_every: usize,
    /// Stream one JSON object per optimization step to this file
    /// (JSONL): step, loss, acc, lr, grad_norm, nan_skips,
    /// steps_per_sec.  Purely observational — the training computation
    /// is untouched whether or not the stream is on.
    pub metrics_out: Option<PathBuf>,
    /// When tracing is on (`CAST_TRACE=1`), also emit a per-op
    /// time-share record into the metrics stream every N steps
    /// (0 disables the share records; the per-step lines still flow).
    pub metrics_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            schedule: Schedule::Warmup { lr: 1e-3, warmup: 20 },
            seed: 0,
            eval_every: 0,
            eval_batches: 8,
            data_workers: 2,
            queue_depth: 4,
            log_every: 10,
            checkpoint: None,
            ckpt_every: 0,
            metrics_out: None,
            metrics_every: 50,
        }
    }
}

pub struct TrainReport {
    pub history: History,
    pub final_train_loss: f32,
    pub final_train_acc: f32,
    pub best_eval_acc: Option<f32>,
    pub steps_per_sec: f64,
}

/// JSONL metrics stream behind `--metrics-out`.  Write failures are
/// logged once and the sink goes quiet — losing the stream must not
/// kill a training run, same policy as checkpoint saves.
struct MetricsSink {
    out: Option<std::io::BufWriter<std::fs::File>>,
}

impl MetricsSink {
    fn open(path: Option<&Path>) -> Result<MetricsSink> {
        let out = match path {
            Some(p) => {
                let f = std::fs::File::create(p)
                    .with_context(|| format!("creating metrics stream {p:?}"))?;
                Some(std::io::BufWriter::new(f))
            }
            None => None,
        };
        Ok(MetricsSink { out })
    }

    fn write(&mut self, line: &Json) {
        use std::io::Write;
        let Some(w) = self.out.as_mut() else { return };
        // one object per line, flushed so `tail -f` tracks live runs
        let mut s = line.to_string();
        s.push('\n');
        let ok = w.write_all(s.as_bytes()).and_then(|()| w.flush());
        if let Err(e) = ok {
            crate::info!("metrics stream write failed (training continues): {e}");
            self.out = None;
        }
    }

    /// Per-step record.  A skipped (non-finite) step reports
    /// `"loss": null` so downstream parsers see the gap explicitly.
    #[allow(clippy::too_many_arguments)]
    fn step_line(
        &mut self,
        step: usize,
        loss: f32,
        acc: f32,
        lr: f32,
        seconds: f64,
        grad_norm: f32,
        nan_skips: usize,
    ) {
        if self.out.is_none() {
            return;
        }
        let loss_j = if loss.is_finite() { Json::num(loss as f64) } else { Json::Null };
        self.write(&Json::obj(vec![
            ("kind", Json::str("step")),
            ("step", Json::num(step as f64)),
            ("loss", loss_j),
            ("acc", Json::num(acc as f64)),
            ("lr", Json::num(lr as f64)),
            ("grad_norm", Json::num(grad_norm as f64)),
            ("nan_skips", Json::num(nan_skips as f64)),
            ("steps_per_sec", Json::num(1.0 / seconds.max(1e-9))),
        ]));
    }

    /// Per-op time-share record (tracing on): drains the spans
    /// accumulated since the last record so each entry covers one
    /// window of `metrics_every` steps.
    fn shares_line(&mut self, step: usize) {
        if self.out.is_none() {
            return;
        }
        let stats = trace::summarize(&trace::drain().spans);
        if stats.is_empty() {
            return;
        }
        let ops: Vec<Json> = stats
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("op", Json::str(s.name)),
                    ("calls", Json::num(s.calls as f64)),
                    ("self_ms", Json::num(s.self_ms)),
                    ("share_pct", Json::num(s.share_pct)),
                ])
            })
            .collect();
        self.write(&Json::obj(vec![
            ("kind", Json::str("op_shares")),
            ("step", Json::num(step as f64)),
            ("ops", Json::Arr(ops)),
        ]));
    }

    /// Per-layer cluster-health record (CAST_CLUSTER_STATS on): drains
    /// the accumulator so each record covers one window of
    /// `metrics_every` steps, and logs a collapse early warning the
    /// first window a layer latches it.
    fn clusters_line(&mut self, step: usize) {
        let snaps = cluster_stats::snapshot();
        cluster_stats::clear();
        if snaps.is_empty() {
            return;
        }
        let collapsed: Vec<i32> =
            snaps.iter().filter(|s| s.collapsed).map(|s| s.layer).collect();
        if !collapsed.is_empty() {
            crate::info!(
                "cluster-collapse warning at step {step}: layer(s) {collapsed:?} dominated by \
                 one cluster (max_fraction >= {} or entropy <= {})",
                cluster_stats::COLLAPSE_MAX_FRACTION,
                cluster_stats::COLLAPSE_MIN_ENTROPY
            );
        }
        if self.out.is_none() {
            return; // the warning above still fires without a stream
        }
        let layers: Vec<Json> = snaps
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("layer", Json::num(s.layer as f64)),
                    ("n_c", Json::num(s.n_c as f64)),
                    ("forwards", Json::num(s.forwards as f64)),
                    ("entropy", Json::num(s.entropy)),
                    ("balance_cv", Json::num(s.balance_cv)),
                    ("max_fraction", Json::num(s.max_fraction)),
                    ("churn", Json::num(s.churn)),
                    ("collapsed", Json::Bool(s.collapsed)),
                ])
            })
            .collect();
        self.write(&Json::obj(vec![
            ("kind", Json::str("cluster_health")),
            ("step", Json::num(step as f64)),
            ("collapsed_layers", Json::num(collapsed.len() as f64)),
            ("layers", Json::Arr(layers)),
        ]));
    }
}

/// Floor for the automatic LR backoff: even a long streak of
/// non-finite steps can't drive the effective LR below lr/1024.
const MIN_LR_SCALE: f32 = 1.0 / 1024.0;
/// Per-good-step recovery factor (2^(1/8)): eight clean steps undo one
/// halving, so a transient spike doesn't permanently slow training.
const LR_SCALE_GROWTH: f32 = 1.090_507_7;

pub struct Trainer {
    engine: Arc<Engine>,
    pub manifest: Manifest,
    train_exe: Arc<dyn Executable>,
    predict_exe: Option<Arc<dyn Executable>>,
    pub state: ModelState,
    gen: Arc<dyn TaskGen>,
    cfg: TrainConfig,
    /// Loss-scale-style LR backoff: halves on a non-finite step, creeps
    /// back toward 1.0 on good steps.  Stays at 1.0 on healthy runs, so
    /// the bit-identical determinism contract is unaffected.
    lr_scale: f32,
    /// Steps skipped because their loss was non-finite (injected or
    /// organic) — the update was dropped before touching params/moments.
    pub nan_skips: usize,
}

impl Trainer {
    pub fn new(
        engine: Arc<Engine>,
        manifest: Manifest,
        cfg: TrainConfig,
        init_seed: u32,
    ) -> Result<Trainer> {
        let gen: Arc<dyn TaskGen> = Arc::from(data::task(&manifest.meta.task)?);
        anyhow::ensure!(
            gen.vocab() <= manifest.meta.vocab,
            "task vocab {} exceeds model vocab {}",
            gen.vocab(),
            manifest.meta.vocab
        );
        let train_exe = engine.load(&manifest, "train_step")?;
        let predict_exe = if engine.has(&manifest, "predict") {
            Some(engine.load(&manifest, "predict")?)
        } else {
            None
        };
        let state = ModelState::init(&engine, &manifest, init_seed)?;
        crate::info!(
            "trainer: {} — {} params ({} tensors), task {}, seq {}, batch {}",
            manifest.key,
            state.total_elems(),
            state.n_params(),
            manifest.meta.task,
            manifest.meta.seq_len,
            manifest.meta.batch
        );
        Ok(Trainer {
            engine,
            manifest,
            train_exe,
            predict_exe,
            state,
            gen,
            cfg,
            lr_scale: 1.0,
            nan_skips: 0,
        })
    }

    /// Load a checkpoint into the trainer: parameters, AdamW moment
    /// buffers (`m`/`v`), and the step counter all restore, so the
    /// optimizer state is complete — every subsequent `train_step` is
    /// bit-identical to the one an uninterrupted process would run from
    /// the same state (see `integration_native.rs`).  Note that `run()`
    /// itself restarts the batch stream and LR schedule at position 0;
    /// continuing a schedule mid-flight is the caller's choice of
    /// `--steps`/`--warmup`/`--seed`.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        // scan backward through the rotation: a torn or corrupt primary
        // (rejected by its digest trailer) falls back to <path>.prev
        let (state, names, from) = checkpoint::load_auto(path)?;
        anyhow::ensure!(
            names.len() == self.manifest.params.len(),
            "checkpoint has {} params, manifest {} — wrong model?",
            names.len(),
            self.manifest.params.len()
        );
        for (name, spec) in names.iter().zip(&self.manifest.params) {
            anyhow::ensure!(
                name == &spec.name,
                "checkpoint parameter {name:?} does not match manifest {:?}",
                spec.name
            );
        }
        crate::info!("resume: {} params from {from:?} at step {}", names.len(), state.step);
        self.state = state;
        Ok(())
    }

    /// One optimization step on the given batch. Returns (loss, acc).
    /// A non-finite loss (organic overflow or the `train.step.nan`
    /// fault point) skips the update entirely — params and AdamW
    /// moments stay untouched, the effective LR backs off, and the
    /// returned loss is NaN so callers can drop the step from history.
    pub fn step(&mut self, batch: Batch, lr: f32) -> Result<(f32, f32)> {
        let lr = lr * self.lr_scale;
        // CAST_CLONE_INPUTS=1 selects the pre-optimization path (clones the
        // full 3P-tensor state per step) — kept so the borrowed-assembly
        // speedup stays A/B-measurable (DESIGN.md §Performance).
        let outputs = if std::env::var_os("CAST_CLONE_INPUTS").is_some() {
            let inputs = self.state.train_inputs(lr, batch.tokens, batch.labels);
            self.train_exe.run(&inputs).context("train_step execution")?
        } else {
            // borrowed assembly: no clone of the 3P-tensor state per step
            let scalars = (HostTensor::scalar_f32(self.state.step), HostTensor::scalar_f32(lr));
            let inputs = self.state.train_inputs_refs(&scalars, &batch.tokens, &batch.labels);
            self.train_exe.run_refs(&inputs).context("train_step execution")?
        };
        self.finish_step(outputs)
    }

    /// Inspect the step's loss *before* absorbing the outputs:
    /// `ModelState::absorb` replaces params and both moment buffers
    /// wholesale, so skipping the absorb is exactly "never write NaN
    /// into the optimizer state".
    fn finish_step(&mut self, outputs: Vec<HostTensor>) -> Result<(f32, f32)> {
        let injected = crate::util::fault::flag("train.step.nan");
        // outputs layout: params' ++ m' ++ v' ++ [step', loss, acc]
        let loss = outputs
            .len()
            .checked_sub(2)
            .and_then(|i| outputs[i].as_f32().ok())
            .and_then(|v| v.first().copied())
            .unwrap_or(f32::NAN);
        if injected || !loss.is_finite() {
            self.nan_skips += 1;
            self.lr_scale = (self.lr_scale * 0.5).max(MIN_LR_SCALE);
            crate::info!(
                "train: non-finite loss{} at optimizer step {} — skipping update \
                 ({} skips so far, lr scale {:.4})",
                if injected { " (injected)" } else { "" },
                self.state.step,
                self.nan_skips,
                self.lr_scale
            );
            return Ok((f32::NAN, 0.0));
        }
        if self.lr_scale < 1.0 {
            self.lr_scale = (self.lr_scale * LR_SCALE_GROWTH).min(1.0);
        }
        self.state.absorb(outputs)
    }

    /// Evaluate accuracy on `n_batches` held-out batches (disjoint stream).
    pub fn evaluate(&self, n_batches: usize) -> Result<(f32, f32)> {
        let exe = self
            .predict_exe
            .as_ref()
            .context("no predict artifact for evaluation")?;
        let meta = &self.manifest.meta;
        let mut stream = crate::data::batcher::SyncStream::new(
            self.gen.clone(),
            self.cfg.seed ^ 0xE7A1_0000_0000_0000, // held-out stream
            meta.batch,
            meta.seq_len,
        );
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut loss_sum = 0.0f64;
        for _ in 0..n_batches {
            let batch = stream.next();
            let mut inputs: Vec<&HostTensor> = self.state.params.iter().collect();
            inputs.push(&batch.tokens);
            let out = exe.run_refs(&inputs).context("predict execution")?;
            let logits = &out[0];
            let labels = batch.labels.as_s32()?;
            let (c, l) = score_logits(logits, labels)?;
            correct += c;
            total += labels.len();
            loss_sum += l as f64 * labels.len() as f64;
        }
        Ok((correct as f32 / total as f32, (loss_sum / total as f64) as f32))
    }

    /// Run the configured number of steps.
    pub fn run(&mut self) -> Result<TrainReport> {
        let meta = &self.manifest.meta;
        let mut batcher = Batcher::spawn(
            self.gen.clone(),
            self.cfg.seed,
            meta.batch,
            meta.seq_len,
            self.cfg.data_workers,
            self.cfg.queue_depth,
        );
        let mut history = History::default();
        let mut metrics = MetricsSink::open(self.cfg.metrics_out.as_deref())?;
        for step in 0..self.cfg.steps {
            let lr = self.cfg.schedule.at(step);
            let batch = batcher.next();
            let t = Timer::start();
            let (loss, acc) = self.step(batch, lr)?;
            let seconds = t.seconds();
            // skipped (non-finite) steps stay out of the history so loss
            // curves and --assert-improves see only applied updates
            if loss.is_finite() {
                history.push_step(StepRecord { step, loss, acc, lr, seconds });
            }
            let gnorm = crate::runtime::native::model::last_grad_norm();
            metrics.step_line(step, loss, acc, lr, seconds, gnorm, self.nan_skips);
            if trace::active()
                && self.cfg.metrics_every > 0
                && (step + 1) % self.cfg.metrics_every == 0
            {
                metrics.shares_line(step);
            }
            if cluster_stats::active()
                && self.cfg.metrics_every > 0
                && (step + 1) % self.cfg.metrics_every == 0
            {
                metrics.clusters_line(step);
            }
            if self.cfg.ckpt_every > 0 && (step + 1) % self.cfg.ckpt_every == 0 {
                self.save_checkpoint_logged();
            }
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                crate::info!(
                    "step {step:5}  loss {loss:.4}  acc {acc:.3}  lr {lr:.2e}  {:.2} steps/s",
                    1.0 / seconds.max(1e-9)
                );
            }
            if self.cfg.eval_every > 0
                && step > 0
                && step % self.cfg.eval_every == 0
                && self.predict_exe.is_some()
            {
                let (eacc, eloss) = self.evaluate(self.cfg.eval_batches)?;
                crate::info!("eval @ {step}: acc {eacc:.3} loss {eloss:.4}");
                history.push_eval(EvalRecord { step, acc: eacc, loss: eloss });
            }
        }
        if self.predict_exe.is_some() && self.cfg.eval_batches > 0 {
            let (eacc, eloss) = self.evaluate(self.cfg.eval_batches)?;
            history.push_eval(EvalRecord { step: self.cfg.steps, acc: eacc, loss: eloss });
            crate::info!("final eval: acc {eacc:.3} loss {eloss:.4}");
        }
        self.save_checkpoint_logged();
        Ok(TrainReport {
            final_train_loss: history.recent_loss(20),
            final_train_acc: history.recent_acc(20),
            best_eval_acc: history.best_eval_acc(),
            steps_per_sec: history.steps_per_sec(),
            history,
        })
    }

    /// Save the configured checkpoint (if any), returning whether it
    /// succeeded.  Failures are logged, not fatal: losing one periodic
    /// snapshot must not kill a long training run — the atomic write
    /// protocol guarantees the previous good generation survives as
    /// `<ckpt>.prev` (or untouched at `<ckpt>` if the tmp write failed).
    pub fn save_checkpoint_logged(&self) -> bool {
        let Some(path) = &self.cfg.checkpoint else { return false };
        let names: Vec<String> = self.manifest.params.iter().map(|p| p.name.clone()).collect();
        match checkpoint::save(&self.state, &names, path) {
            Ok(()) => {
                crate::info!("checkpoint -> {path:?} (optimizer step {})", self.state.step);
                true
            }
            Err(e) => {
                crate::info!("checkpoint save failed (training continues): {e:#}");
                false
            }
        }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }
}

/// Argmax accuracy + mean NLL from logits against labels.
pub fn score_logits(logits: &HostTensor, labels: &[i32]) -> Result<(usize, f32)> {
    let v = logits.as_f32()?;
    let b = labels.len();
    anyhow::ensure!(
        logits.shape.len() == 2 && logits.shape[0] == b,
        "logits shape {:?} vs {} labels",
        logits.shape,
        b
    );
    let c = logits.shape[1];
    let mut correct = 0usize;
    let mut nll = 0.0f64;
    for (i, &label) in labels.iter().enumerate() {
        let row = &v[i * c..(i + 1) * c];
        let mut arg = 0;
        for (j, &x) in row.iter().enumerate() {
            if x > row[arg] {
                arg = j;
            }
        }
        if arg as i32 == label {
            correct += 1;
        }
        // stable log-softmax NLL
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|&x| (x - m).exp()).sum();
        nll += -((row[label as usize] - m) - z.ln()) as f64;
    }
    Ok((correct, (nll / b as f64) as f32))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_logits_counts_correct() {
        let logits = HostTensor::f32(vec![3, 2], vec![2.0, 1.0, 0.0, 3.0, 1.0, 1.0]);
        let (correct, nll) = score_logits(&logits, &[0, 1, 0]).unwrap();
        assert_eq!(correct, 3); // third row is a tie -> first max -> class 0
        assert!(nll > 0.0);
        let (c2, _) = score_logits(&logits, &[1, 0, 1]).unwrap();
        assert_eq!(c2, 0);
    }

    #[test]
    fn score_logits_shape_mismatch() {
        let logits = HostTensor::f32(vec![2, 2], vec![0.0; 4]);
        assert!(score_logits(&logits, &[0, 1, 0]).is_err());
    }
}
