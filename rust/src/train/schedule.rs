//! Learning-rate schedules.  The LR is an *input* of the train_step
//! artifact, so schedules live entirely in L3 and need no re-lowering.

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Constant { lr: f32 },
    /// Linear warmup to `lr`, then constant (LRA default).
    Warmup { lr: f32, warmup: usize },
    /// Linear warmup then cosine decay to `floor` at `total` steps.
    WarmupCosine { lr: f32, warmup: usize, total: usize, floor: f32 },
}

impl Schedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::Warmup { lr, warmup } => {
                if warmup == 0 || step >= warmup {
                    lr
                } else {
                    lr * (step + 1) as f32 / warmup as f32
                }
            }
            Schedule::WarmupCosine { lr, warmup, total, floor } => {
                if step < warmup {
                    return lr * (step + 1) as f32 / warmup.max(1) as f32;
                }
                if step >= total {
                    return floor;
                }
                let t = (step - warmup) as f32 / (total - warmup).max(1) as f32;
                floor + 0.5 * (lr - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::Warmup { lr: 1.0, warmup: 10 };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert_eq!(s.at(10), 1.0);
        assert_eq!(s.at(1000), 1.0);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = Schedule::WarmupCosine { lr: 1.0, warmup: 2, total: 102, floor: 0.1 };
        assert!(s.at(1) <= 1.0);
        assert_eq!(s.at(500), 0.1);
        let mid = s.at(52);
        assert!(mid < 1.0 && mid > 0.1, "mid {mid}");
        // monotone non-increasing after warmup
        let mut prev = s.at(2);
        for step in 3..102 {
            let cur = s.at(step);
            assert!(cur <= prev + 1e-6);
            prev = cur;
        }
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 0.5 };
        assert_eq!(s.at(0), 0.5);
        assert_eq!(s.at(9999), 0.5);
    }
}
