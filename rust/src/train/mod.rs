//! L3 training runtime: loop, LR schedules, metrics.

pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use metrics::History;
pub use schedule::Schedule;
pub use trainer::{score_logits, TrainConfig, Trainer, TrainReport};
